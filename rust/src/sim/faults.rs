//! Deterministic fault injection (PR-10): a seeded, typed fault
//! vocabulary perturbing the round loop, with the same counter-based
//! stream discipline as [`crate::sim::population`].
//!
//! A [`FaultPlan`] declares *rates* and *durations* for four runtime
//! fault classes:
//!
//! * **crash** — a client goes dark mid-round and stays offline for
//!   `crash_rounds` rounds (it holds its subchannels but contributes
//!   neither compute nor uploads, exactly like a dropout);
//! * **stall** — a transient device compute stall: the client's
//!   `f_cycles` is multiplied by `stall_factor` for `stall_rounds`
//!   rounds;
//! * **outage** — a subchannel outage on the main uplink: the client's
//!   channel gain is attenuated by `outage_factor` (0 = total outage)
//!   for `outage_rounds` rounds, applied through the
//!   [`crate::net::Link::mask_client_gains`] mask;
//! * **blackout** — a federated-server blackout: every client's fed
//!   uplink gain is attenuated by `blackout_factor` for
//!   `blackout_rounds` rounds
//!   ([`crate::net::Link::attenuate_all_gains`]).
//!
//! The two remaining members of the fault vocabulary — corrupted /
//! truncated checkpoint bytes and malformed event-stream lines — are
//! *input* faults, not runtime faults: they are exercised by the CRC
//! footer tests ([`crate::util::codec::check_crc`]) and the lenient
//! replay parser ([`crate::service::event::parse_events_lenient`]).
//!
//! **Determinism theorem.** Every draw the injector ever takes comes
//! from a counter-based stream that is a pure function of
//! `(plan.seed, TAG_FAULT, onset round, fault class)` — the discipline
//! of [`crate::sim::population::stream`]. Consequences:
//!
//! 1. The injector is **stateless**: [`FaultInjector::overlay`] is a
//!    pure function of `(plan, round, k)`, so identical seeds replay
//!    identical fault schedules — across runs, across checkpoint/resume
//!    boundaries (nothing about the schedule needs serializing), and
//!    across processes.
//! 2. The injector consumes **zero** draws from the dynamics streams
//!    (`jitter`, `dropout`, channel process) — it owns its own seed and
//!    tag — so attaching an *empty* plan, or removing a plan, moves no
//!    bits in any existing run (`rust/tests/prop_faults.rs` pins this
//!    byte-for-byte on every preset).
//! 3. Fault classes draw from per-class streams, so tuning one class's
//!    rate never shifts another class's schedule.
//!
//! Faults start at round >= 1: round 0 is the initial solve on the
//! static scenario, which stays pristine by construction.

use anyhow::{bail, Result};

use crate::config::FaultsConfig;
use crate::delay::Scenario;
use crate::sim::population::stream;
use crate::util::rng::Rng;

/// Stream purpose tag: fault-injection draws (see
/// [`crate::sim::population::stream`]; the other tags live there).
pub(crate) const TAG_FAULT: u64 = 0xFA17;

/// Per-class sub-stream ids (the `b` coordinate of [`stream`]).
const CLASS_CRASH: u64 = 0;
const CLASS_STALL: u64 = 1;
const CLASS_OUTAGE: u64 = 2;
const CLASS_BLACKOUT: u64 = 3;

/// A declarative fault schedule: rates, severities, and durations for
/// the four runtime fault classes. The empty (all-rates-zero) plan is
/// the identity: attaching it to a run moves no bits.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Seed of the injector's own stream family (independent of the
    /// population/dynamics seeds).
    pub seed: u64,
    /// Per-client per-round crash probability.
    pub crash_rate: f64,
    /// Rounds a crashed client stays offline (>= 1).
    pub crash_rounds: usize,
    /// Per-client per-round compute-stall probability.
    pub stall_rate: f64,
    /// Multiplier on a stalled client's `f_cycles`, in (0, 1].
    pub stall_factor: f64,
    pub stall_rounds: usize,
    /// Per-client per-round main-uplink outage probability.
    pub outage_rate: f64,
    /// Linear gain multiplier under outage, in [0, 1] (0 = total
    /// outage: the client's rate is 0 on every subchannel, which is
    /// what drives solves infeasible and exercises the repair chain).
    pub outage_factor: f64,
    pub outage_rounds: usize,
    /// Per-round federated-server blackout probability.
    pub blackout_rate: f64,
    /// Linear gain multiplier on every fed-uplink gain, in [0, 1].
    pub blackout_factor: f64,
    pub blackout_rounds: usize,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan {
            seed: 0xFA17,
            crash_rate: 0.0,
            crash_rounds: 1,
            stall_rate: 0.0,
            stall_factor: 0.5,
            stall_rounds: 1,
            outage_rate: 0.0,
            outage_factor: 0.0,
            outage_rounds: 1,
            blackout_rate: 0.0,
            blackout_factor: 1e-4,
            blackout_rounds: 1,
        }
    }
}

fn parse_f64(what: &str, s: &str) -> Result<f64> {
    match s.parse::<f64>() {
        Ok(v) if v.is_finite() => Ok(v),
        _ => bail!("bad {what} '{s}' in fault spec (want a finite number)"),
    }
}

fn parse_usize(what: &str, s: &str) -> Result<usize> {
    s.parse::<usize>()
        .map_err(|e| anyhow::anyhow!("bad {what} '{s}' in fault spec: {e}"))
}

impl FaultPlan {
    /// True when no runtime fault can ever fire (the identity plan).
    pub fn is_empty(&self) -> bool {
        self.crash_rate == 0.0
            && self.stall_rate == 0.0
            && self.outage_rate == 0.0
            && self.blackout_rate == 0.0
    }

    /// Parse a `--faults` spec: comma-separated `key=args` sections
    /// with colon-separated args, e.g.
    /// `crash=0.1:2,stall=0.05:0.5:1,outage=0.1:0:2,blackout=0.02:1e-4:1,seed=7`.
    ///
    /// * `crash=RATE[:ROUNDS]`
    /// * `stall=RATE[:FACTOR[:ROUNDS]]`
    /// * `outage=RATE[:FACTOR[:ROUNDS]]`
    /// * `blackout=RATE[:FACTOR[:ROUNDS]]`
    /// * `seed=U64`
    ///
    /// Omitted args keep the [`FaultPlan::default`] values; `none` (or
    /// an empty spec) is the empty plan. [`FaultPlan::label`] emits a
    /// spec this function round-trips.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(plan);
        }
        for section in spec.split(',') {
            let section = section.trim();
            if section.is_empty() {
                continue;
            }
            let (key, args) = match section.split_once('=') {
                Some((k, a)) => (k.trim(), a.trim()),
                None => bail!(
                    "bad fault section '{section}' (want key=args; keys: \
                     crash, stall, outage, blackout, seed)"
                ),
            };
            let parts: Vec<&str> = args.split(':').map(str::trim).collect();
            match key {
                "crash" => {
                    plan.crash_rate = parse_f64("crash rate", parts[0])?;
                    if let Some(p) = parts.get(1) {
                        plan.crash_rounds = parse_usize("crash rounds", p)?;
                    }
                    if parts.len() > 2 {
                        bail!("crash takes at most RATE:ROUNDS, got '{args}'");
                    }
                }
                "stall" => {
                    plan.stall_rate = parse_f64("stall rate", parts[0])?;
                    if let Some(p) = parts.get(1) {
                        plan.stall_factor = parse_f64("stall factor", p)?;
                    }
                    if let Some(p) = parts.get(2) {
                        plan.stall_rounds = parse_usize("stall rounds", p)?;
                    }
                    if parts.len() > 3 {
                        bail!("stall takes at most RATE:FACTOR:ROUNDS, got '{args}'");
                    }
                }
                "outage" => {
                    plan.outage_rate = parse_f64("outage rate", parts[0])?;
                    if let Some(p) = parts.get(1) {
                        plan.outage_factor = parse_f64("outage factor", p)?;
                    }
                    if let Some(p) = parts.get(2) {
                        plan.outage_rounds = parse_usize("outage rounds", p)?;
                    }
                    if parts.len() > 3 {
                        bail!("outage takes at most RATE:FACTOR:ROUNDS, got '{args}'");
                    }
                }
                "blackout" => {
                    plan.blackout_rate = parse_f64("blackout rate", parts[0])?;
                    if let Some(p) = parts.get(1) {
                        plan.blackout_factor = parse_f64("blackout factor", p)?;
                    }
                    if let Some(p) = parts.get(2) {
                        plan.blackout_rounds = parse_usize("blackout rounds", p)?;
                    }
                    if parts.len() > 3 {
                        bail!("blackout takes at most RATE:FACTOR:ROUNDS, got '{args}'");
                    }
                }
                "seed" => {
                    plan.seed = parts[0].parse::<u64>().map_err(|e| {
                        anyhow::anyhow!("bad fault seed '{}': {e}", parts[0])
                    })?;
                    if parts.len() > 1 {
                        bail!("seed takes one value, got '{args}'");
                    }
                }
                _ => bail!(
                    "unknown fault key '{key}' (available: crash, stall, outage, \
                     blackout, seed)"
                ),
            }
        }
        plan.validate()?;
        Ok(plan)
    }

    /// Lift the TOML `[faults]` section into a plan.
    pub fn from_config(cfg: &FaultsConfig) -> Result<FaultPlan> {
        let plan = FaultPlan {
            seed: cfg.seed,
            crash_rate: cfg.crash_rate,
            crash_rounds: cfg.crash_rounds,
            stall_rate: cfg.stall_rate,
            stall_factor: cfg.stall_factor,
            stall_rounds: cfg.stall_rounds,
            outage_rate: cfg.outage_rate,
            outage_factor: cfg.outage_factor,
            outage_rounds: cfg.outage_rounds,
            blackout_rate: cfg.blackout_rate,
            blackout_factor: cfg.blackout_factor,
            blackout_rounds: cfg.blackout_rounds,
        };
        plan.validate()?;
        Ok(plan)
    }

    /// Validate rates / factors / durations; every path into a plan
    /// (spec, TOML, literals via callers) funnels through this.
    pub fn validate(&self) -> Result<()> {
        for (what, rate) in [
            ("crash", self.crash_rate),
            ("stall", self.stall_rate),
            ("outage", self.outage_rate),
            ("blackout", self.blackout_rate),
        ] {
            if !(0.0..=1.0).contains(&rate) {
                bail!("fault {what} rate must be in [0, 1], got {rate}");
            }
        }
        if !(self.stall_factor > 0.0 && self.stall_factor <= 1.0) {
            bail!(
                "stall factor must be in (0, 1] (0 would mean a dead device — \
                 use crash), got {}",
                self.stall_factor
            );
        }
        for (what, f) in [("outage", self.outage_factor), ("blackout", self.blackout_factor)] {
            if !(0.0..=1.0).contains(&f) {
                bail!("fault {what} factor must be in [0, 1], got {f}");
            }
        }
        for (what, o) in [
            ("crash", self.crash_rounds),
            ("stall", self.stall_rounds),
            ("outage", self.outage_rounds),
            ("blackout", self.blackout_rounds),
        ] {
            if o == 0 {
                bail!("fault {what} duration must be >= 1 round");
            }
        }
        Ok(())
    }

    /// Canonical spec string [`FaultPlan::parse`] round-trips (`none`
    /// for the empty plan; the seed is always emitted otherwise).
    pub fn label(&self) -> String {
        if self.is_empty() {
            return "none".to_string();
        }
        let mut parts = Vec::new();
        if self.crash_rate > 0.0 {
            parts.push(format!("crash={}:{}", self.crash_rate, self.crash_rounds));
        }
        if self.stall_rate > 0.0 {
            parts.push(format!(
                "stall={}:{}:{}",
                self.stall_rate, self.stall_factor, self.stall_rounds
            ));
        }
        if self.outage_rate > 0.0 {
            parts.push(format!(
                "outage={}:{}:{}",
                self.outage_rate, self.outage_factor, self.outage_rounds
            ));
        }
        if self.blackout_rate > 0.0 {
            parts.push(format!(
                "blackout={}:{}:{}",
                self.blackout_rate, self.blackout_factor, self.blackout_rounds
            ));
        }
        parts.push(format!("seed={}", self.seed));
        parts.join(",")
    }
}

/// The faults *active* at one round: what the engines apply on top of
/// the evolved environment before solving and realizing the round.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundOverlay {
    /// Client view-indices forced offline this round (sorted,
    /// deduplicated).
    pub crashed: Vec<usize>,
    /// `(client, factor)` compute stalls (sorted by client).
    pub stalled: Vec<(usize, f64)>,
    /// `(client, factor)` main-uplink gain masks (sorted by client).
    pub outage: Vec<(usize, f64)>,
    /// Uniform fed-uplink gain factor while the federated server is
    /// blacked out.
    pub blackout: Option<f64>,
}

impl RoundOverlay {
    /// True when the round is fault-free (the engines' zero-cost
    /// fast path: nothing is applied, nothing is undone, no bits move).
    pub fn is_empty(&self) -> bool {
        self.crashed.is_empty()
            && self.stalled.is_empty()
            && self.outage.is_empty()
            && self.blackout.is_none()
    }

    /// Number of faults active this round (what
    /// [`crate::sim::RoundRecord::faults`] records).
    pub fn count(&self) -> usize {
        self.crashed.len()
            + self.stalled.len()
            + self.outage.len()
            + usize::from(self.blackout.is_some())
    }
}

/// Apply an overlay's scenario-visible faults (stalls, outages,
/// blackout) to a scenario in place. Membership (crashes) is the
/// caller's: the engines own their availability masks.
pub(crate) fn apply_to_scenario(scn: &mut Scenario, ov: &RoundOverlay) {
    for &(k, factor) in &ov.stalled {
        if let Some(c) = scn.topo.clients.get_mut(k) {
            c.f_cycles *= factor;
        }
    }
    scn.main_link.mask_client_gains(&ov.outage);
    if let Some(factor) = ov.blackout {
        scn.fed_link.attenuate_all_gains(factor);
    }
}

/// The stateless injector: a [`FaultPlan`] plus the pure-function
/// schedule derivation (see the module docs' determinism theorem).
#[derive(Clone, Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector { plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The onset draw for one fault class at one round: a fresh
    /// counter-based stream, one uniform per client (or one total for
    /// the blackout class).
    fn class_stream(&self, onset: usize, class: u64) -> Rng {
        stream(self.plan.seed, TAG_FAULT, onset as u64, class)
    }

    /// Collect the clients whose `class` fault *starts* at `onset`.
    fn onsets(&self, onset: usize, k: usize, rate: f64, class: u64, hit: &mut Vec<usize>) {
        let mut rng = self.class_stream(onset, class);
        for j in 0..k {
            if rng.f64() < rate && !hit.contains(&j) {
                hit.push(j);
            }
        }
    }

    /// Every fault *active* at `round` over a `k`-client view: the
    /// union of onsets over each class's trailing duration window. A
    /// pure function of `(plan, round, k)` — no state, no serialized
    /// schedule, bit-identical replay from any resume point. Round 0
    /// is always fault-free.
    pub fn overlay(&self, round: usize, k: usize) -> RoundOverlay {
        let mut ov = RoundOverlay::default();
        if round == 0 {
            return ov;
        }
        let p = &self.plan;
        // onset window for a duration-o fault active at `round`:
        // max(1, round - o + 1) ..= round
        let window = |dur: usize| (round.saturating_sub(dur - 1).max(1))..=round;
        if p.crash_rate > 0.0 {
            for s in window(p.crash_rounds) {
                self.onsets(s, k, p.crash_rate, CLASS_CRASH, &mut ov.crashed);
            }
            ov.crashed.sort_unstable();
        }
        if p.stall_rate > 0.0 {
            let mut hit = Vec::new();
            for s in window(p.stall_rounds) {
                self.onsets(s, k, p.stall_rate, CLASS_STALL, &mut hit);
            }
            hit.sort_unstable();
            ov.stalled = hit.into_iter().map(|j| (j, p.stall_factor)).collect();
        }
        if p.outage_rate > 0.0 {
            let mut hit = Vec::new();
            for s in window(p.outage_rounds) {
                self.onsets(s, k, p.outage_rate, CLASS_OUTAGE, &mut hit);
            }
            hit.sort_unstable();
            ov.outage = hit.into_iter().map(|j| (j, p.outage_factor)).collect();
        }
        if p.blackout_rate > 0.0 {
            for s in window(p.blackout_rounds) {
                if self.class_stream(s, CLASS_BLACKOUT).f64() < p.blackout_rate {
                    ov.blackout = Some(p.blackout_factor);
                    break;
                }
            }
        }
        ov
    }
}

/// The `chaos` fault-matrix levels: a fixed named ladder of plans so
/// the CLI, CI, and the EXPERIMENTS degradation study all speak the
/// same severities.
pub fn matrix_levels(seed: u64) -> Vec<(&'static str, FaultPlan)> {
    let light = FaultPlan {
        seed,
        crash_rate: 0.05,
        stall_rate: 0.10,
        stall_factor: 0.5,
        outage_rate: 0.05,
        outage_factor: 1e-3,
        blackout_rate: 0.02,
        blackout_factor: 1e-2,
        ..FaultPlan::default()
    };
    let heavy = FaultPlan {
        seed,
        crash_rate: 0.15,
        crash_rounds: 2,
        stall_rate: 0.25,
        stall_factor: 0.25,
        stall_rounds: 2,
        outage_rate: 0.15,
        outage_factor: 0.0,
        outage_rounds: 2,
        blackout_rate: 0.05,
        blackout_factor: 1e-4,
        ..FaultPlan::default()
    };
    vec![("none", FaultPlan::default()), ("light", light), ("heavy", heavy)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_and_none_specs() {
        for spec in ["", "  ", "none"] {
            let p = FaultPlan::parse(spec).unwrap();
            assert!(p.is_empty(), "'{spec}' must parse to the empty plan");
            assert_eq!(p.label(), "none");
        }
        assert!(FaultPlan::default().is_empty());
    }

    #[test]
    fn specs_round_trip_through_label() {
        for spec in [
            "crash=0.1:2,seed=7",
            "stall=0.05:0.5:1,seed=9",
            "outage=0.1:0:2,seed=3",
            "blackout=0.02:0.0001:1,seed=1",
            "crash=0.1:2,stall=0.25:0.25:2,outage=0.15:0:2,blackout=0.05:0.0001:1,seed=42",
        ] {
            let p = FaultPlan::parse(spec).unwrap();
            let again = FaultPlan::parse(&p.label()).unwrap();
            assert_eq!(p, again, "label round-trip for '{spec}' (label: {})", p.label());
        }
        for (name, plan) in matrix_levels(11) {
            let again = FaultPlan::parse(&plan.label()).unwrap();
            assert_eq!(plan, again, "matrix level {name}");
        }
    }

    #[test]
    fn bad_specs_are_rejected_descriptively() {
        for bad in [
            "crash",              // no args
            "crash=x",            // non-numeric rate
            "crash=1.5",          // rate out of range
            "crash=0.1:0",        // zero duration
            "crash=0.1:2:3",      // too many args
            "stall=0.1:0.0",      // dead-device factor
            "stall=0.1:1.5",      // factor out of range
            "outage=0.1:2.0",     // factor out of range
            "seed=abc",
            "quake=0.5",          // unknown key
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should fail");
        }
    }

    #[test]
    fn overlay_is_a_pure_function_of_plan_round_k() {
        let plan = FaultPlan::parse("crash=0.3:2,stall=0.3:0.5:1,outage=0.3:0:1,blackout=0.3:0.0001:1,seed=5")
            .unwrap();
        let inj = FaultInjector::new(plan.clone());
        let inj2 = FaultInjector::new(plan);
        for round in 0..20 {
            assert_eq!(inj.overlay(round, 6), inj2.overlay(round, 6), "round {round}");
        }
        // different seeds give different schedules somewhere
        let other = FaultInjector::new(
            FaultPlan::parse("crash=0.3:2,stall=0.3:0.5:1,outage=0.3:0:1,blackout=0.3:0.0001:1,seed=6")
                .unwrap(),
        );
        assert!(
            (1..20).any(|r| inj.overlay(r, 6) != other.overlay(r, 6)),
            "seed must steer the schedule"
        );
    }

    #[test]
    fn round_zero_is_always_fault_free() {
        let inj = FaultInjector::new(FaultPlan::parse("crash=1.0,seed=1").unwrap());
        assert!(inj.overlay(0, 8).is_empty());
        // rate 1.0 crashes everyone from round 1 on
        assert_eq!(inj.overlay(1, 8).crashed, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn durations_keep_faults_active_across_rounds() {
        // rate 1.0, duration 3: every client is crashed at rounds 1..,
        // and the round-1 onset alone covers rounds 1..=3
        let inj = FaultInjector::new(FaultPlan::parse("crash=1.0:3,seed=2").unwrap());
        for r in 1..=3 {
            assert_eq!(inj.overlay(r, 2).crashed, vec![0, 1], "round {r}");
        }
        // duration windows never reach onset round 0
        let rare = FaultInjector::new(FaultPlan::parse("crash=0.4:5,seed=13").unwrap());
        let o1 = rare.overlay(1, 4);
        // round 1's actives are exactly round 1's onsets (window is 1..=1)
        let mut expect = Vec::new();
        rare.onsets(1, 4, 0.4, CLASS_CRASH, &mut expect);
        expect.sort_unstable();
        assert_eq!(o1.crashed, expect);
    }

    #[test]
    fn classes_draw_from_independent_streams() {
        // toggling the stall class must not shift the crash schedule
        let both = FaultInjector::new(FaultPlan::parse("crash=0.3,stall=0.3,seed=4").unwrap());
        let crash_only = FaultInjector::new(FaultPlan::parse("crash=0.3,seed=4").unwrap());
        for r in 1..30 {
            assert_eq!(
                both.overlay(r, 10).crashed,
                crash_only.overlay(r, 10).crashed,
                "round {r}"
            );
        }
    }

    #[test]
    fn apply_to_scenario_masks_gains_and_compute() {
        let mut scn = crate::delay::testutil::toy_scenario();
        let g0_main = scn.main_link.client_gain.clone();
        let g0_fed = scn.fed_link.client_gain.clone();
        let f0 = scn.topo.clients[0].f_cycles;
        let ov = RoundOverlay {
            crashed: vec![],
            stalled: vec![(0, 0.5)],
            outage: vec![(1, 0.0)],
            blackout: Some(1e-2),
        };
        apply_to_scenario(&mut scn, &ov);
        assert_eq!(scn.topo.clients[0].f_cycles.to_bits(), (f0 * 0.5).to_bits());
        assert_eq!(scn.main_link.client_gain[0].to_bits(), g0_main[0].to_bits());
        assert_eq!(scn.main_link.client_gain[1], 0.0);
        for (g, g0) in scn.fed_link.client_gain.iter().zip(&g0_fed) {
            assert_eq!(g.to_bits(), (g0 * 1e-2).to_bits());
        }
        // out-of-range indices are ignored, not a panic (fault indices
        // come from the per-round view size, but stay defensive)
        let wild = RoundOverlay {
            stalled: vec![(99, 0.5)],
            outage: vec![(99, 0.0)],
            ..RoundOverlay::default()
        };
        apply_to_scenario(&mut scn, &wild);
    }
}
