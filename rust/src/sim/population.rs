//! Event-driven population engine: 10^5–10^6 modeled clients, O(cohort)
//! per-round simulation.
//!
//! [`crate::sim::RoundSimulator`] iterates every client every round —
//! the right model for the paper's K = 5 testbed, hopeless for a
//! production deployment where a coordinator samples a small cohort out
//! of a huge fleet each round (xaynet's invite/aggregate lifecycle).
//! [`Population`] models that fleet without ever holding it in memory:
//!
//! * **Per-client forked streams.** Every random quantity a client ever
//!   produces comes from a counter-based stream that is a pure function
//!   of `(seed, purpose tag, client id, round)` — see [`stream`]. No
//!   client shares RNG state with any other, so client `i`'s trajectory
//!   is bit-identical no matter which *other* clients were selected, in
//!   what order, or on how many threads. Geometry and the selection
//!   lifecycle key on `population.seed`; the channel/compute/availability
//!   evolution keys on `dynamics.seed`, preserving the repo-wide
//!   convention that redrawing the environment keeps the geometry fixed.
//! * **Lazy, run-length-compressed state.** A client's state is only
//!   materialized when first observed ([`Population::observe`]), and a
//!   client skipped for `gap` rounds is advanced in O(1): the AR(1)
//!   shadowing jumps through the closed form of
//!   [`crate::net::process::ar1_jump`] (one Gaussian per shadow instead
//!   of `gap`), compute jitter is i.i.d. per round so only the current
//!   round's draw is taken, and the dropout/rejoin 2-state Markov chain
//!   advances through its closed-form `gap`-step marginal
//!   `p_on = π + (s − π)·λ^gap` with `π = q/(p+q)`, `λ = 1 − p − q`.
//!   At `gap = 1` the shadow jump is **bit-identical** to the eager
//!   per-round step (the [`ar1_jump`] exactness contract); at larger
//!   gaps the equivalence is distributional — `gap` steps consume `gap`
//!   Gaussians while the jump consumes one, so no path-bitwise
//!   equality across decompositions can exist (see DESIGN.md, PR-6).
//! * **Cohort lowering.** Each round a [`Selector`] invites
//!   `min(cohort, size)` clients; only they are observed and lowered
//!   into a [`Scenario`] *view* (the template scenario with the
//!   cohort's sites and gains spliced in) that hits the incremental
//!   solver stack — [`crate::delay::WorkloadCache`] for the workload
//!   table, [`crate::delay::ColumnCache`] for delta rate columns, and
//!   the policies' warm-started BCD — so per-round cost is O(cohort),
//!   independent of population size.
//!
//! [`PopulationSimulator`] replays the whole fine-tuning run over that
//! lifecycle and reuses [`RoundRecord`]/[`DynamicOutcome`] accounting.
//! Two extra production effects are first-class:
//!
//! * **Straggler deadlines** (`population.deadline_drop = x`): after
//!   the round's allocation is fixed, the slowest `⌊x·online⌋` cohort
//!   members (by realized client-side phase delay `T_k^F + T_k^s +
//!   T_k^B + T_k^f`) are cut from the round's aggregate — they still
//!   held their subchannels, but contribute neither delay nor energy,
//!   exactly like a dropout that round.
//! * **Dropout / rendezvous-rejoin**: selection is availability-blind
//!   (invitees may turn out offline, as in xaynet's invite-then-wait
//!   coordinator); offline invitees are masked out of the aggregate
//!   and rejoin through the Markov chain above.
//!
//! **Anchor invariant** (property-tested in
//! `rust/tests/prop_population.rs` and the module tests): a degenerate
//! population — `population == K`, a full-participation selector, no
//! deadline — reproduces [`RoundSimulator`] on
//! [`Population::scenario`] **bit for bit**. In that dense regime the
//! engine switches to the exact shared-stream evolution the round
//! simulator uses (one AR(1) process, one jitter stream, one dropout
//! stream over all K clients), so every record, every re-solve
//! decision, and both realized totals carry identical bits.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::Config;
use crate::delay::{Allocation, ConvergenceModel, Scenario, WorkloadCache};
use crate::model::WorkloadTable;
use crate::net::power::db_to_linear;
use crate::net::process::ar1_jump;
use crate::net::topology::ClientSite;
use crate::net::ChannelModel;
use crate::opt::policy::AllocationPolicy;
use crate::opt::{bcd, power, Objective};
use crate::sim::builder::ScenarioBuilder;
use crate::sim::dynamic::{DynamicOutcome, ReOptStrategy, RoundCost};
use crate::sim::engine::{DriftEnv, RoundCore, StepCtx};
use crate::sim::faults::{apply_to_scenario, FaultInjector, FaultPlan};
use crate::sim::selector::{parse_selector, SelectionCtx, Selector, WeightIndex};
use crate::util::rng::Rng;

/// Stream purpose tag: per-client static draws (placement, compute
/// capability, initial shadowing).
pub(crate) const TAG_STATIC: u64 = 0x51A7;
/// Stream purpose tag: per-(client, round) observation draws (shadow
/// innovations, jitter, availability).
pub(crate) const TAG_OBSERVE: u64 = 0x0B5E;
/// Stream purpose tag: per-round cohort selection.
pub(crate) const TAG_SELECT: u64 = 0x5E1E;

/// Counter-based stream derivation: a pure function of
/// `(seed, tag, a, b)`, so any draw in the population is addressable
/// without materializing any other. The odd multipliers decorrelate the
/// coordinates before `Rng::new`'s SplitMix64 expansion scrambles the
/// combined key.
pub(crate) fn stream(seed: u64, tag: u64, a: u64, b: u64) -> Rng {
    Rng::new(
        seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ a.wrapping_mul(0x2545_F491_4F6C_DD1D)
            ^ b.wrapping_mul(0xD1B5_4A32_D192_ED03),
    )
}

/// One client's state as seen at one round.
#[derive(Clone, Copy, Debug)]
pub struct Observation {
    /// Distance to the main server (m).
    pub d_main_m: f64,
    /// Distance to the federated server (m).
    pub d_fed_m: f64,
    /// Effective compute capability this round (cycles/s; the static
    /// capability rescaled by the round's jitter draw).
    pub f_cycles: f64,
    /// Linear channel gain to the main / federated server.
    pub gain_main: f64,
    pub gain_fed: f64,
    /// Whether the client is reachable this round (dropout/rejoin
    /// chain; round 0 is always online, like the round simulator).
    pub online: bool,
}

/// Materialized state of one client (only selected clients ever get
/// one).
#[derive(Clone, Debug)]
struct ClientSlot {
    /// Static placement and capability (f_cycles = the base f_k).
    site: ClientSite,
    /// AR(1) shadow fading state (dB) on both uplinks.
    shadow_main_db: f64,
    shadow_fed_db: f64,
    /// Effective compute at `last_round` (jittered f_k).
    f_round: f64,
    online: bool,
    /// Round the state above is current for.
    last_round: usize,
}

/// Mutable per-run state of a population: lazily materialized client
/// slots, the invitation history the staleness selector reads, and the
/// lazily built weight index. [`Population`] itself stays immutable so
/// several runs (strategies, policies) can share one population.
pub struct PopulationState {
    slots: BTreeMap<usize, ClientSlot>,
    /// Per-client last-invited round, encoded `round + 1` (0 = never).
    last_invited: Vec<u32>,
    weights: Option<WeightIndex>,
}

impl PopulationState {
    pub fn new(size: usize) -> PopulationState {
        PopulationState {
            slots: BTreeMap::new(),
            last_invited: vec![0; size],
            weights: None,
        }
    }

    /// Distinct clients materialized so far (== distinct clients ever
    /// observed).
    pub fn materialized(&self) -> usize {
        self.slots.len()
    }

    /// Serialize the mutable selection/observation state for the
    /// service checkpoint. The weight index is skipped: it is a pure
    /// function of the population's static draws and is rebuilt lazily,
    /// bit-identically, on first weighted selection after resume.
    pub(crate) fn checkpoint_write(&self, w: &mut crate::util::codec::BinWriter) {
        w.usize(self.slots.len());
        for (&id, s) in &self.slots {
            w.usize(id);
            w.f64(s.site.d_main_m);
            w.f64(s.site.d_fed_m);
            w.f64(s.site.f_cycles);
            w.f64(s.shadow_main_db);
            w.f64(s.shadow_fed_db);
            w.f64(s.f_round);
            w.bool(s.online);
            w.usize(s.last_round);
        }
        w.usize(self.last_invited.len());
        for &v in &self.last_invited {
            w.u32(v);
        }
    }

    /// Inverse of [`PopulationState::checkpoint_write`]; `size` is the
    /// rebuilt population's size, validated against the payload.
    pub(crate) fn checkpoint_read(
        r: &mut crate::util::codec::BinReader,
        size: usize,
    ) -> Result<PopulationState> {
        let n = r.usize("population slot count")?;
        if n > size {
            bail!("corrupt service checkpoint: {n} client slots exceed population size {size}");
        }
        let mut slots = BTreeMap::new();
        for _ in 0..n {
            let id = r.usize("slot id")?;
            if id >= size {
                bail!("corrupt service checkpoint: slot id {id} out of population size {size}");
            }
            let site = ClientSite {
                d_main_m: r.f64("slot d_main")?,
                d_fed_m: r.f64("slot d_fed")?,
                f_cycles: r.f64("slot f_cycles")?,
            };
            let slot = ClientSlot {
                site,
                shadow_main_db: r.f64("slot shadow_main")?,
                shadow_fed_db: r.f64("slot shadow_fed")?,
                f_round: r.f64("slot f_round")?,
                online: r.bool("slot online")?,
                last_round: r.usize("slot last_round")?,
            };
            slots.insert(id, slot);
        }
        let m = r.usize("last_invited length")?;
        if m != size {
            bail!(
                "corrupt service checkpoint: last_invited length {m} != population size {size}"
            );
        }
        let mut last_invited = Vec::with_capacity(m);
        for _ in 0..m {
            last_invited.push(r.u32("last_invited entry")?);
        }
        Ok(PopulationState {
            slots,
            last_invited,
            weights: None,
        })
    }
}

/// An immutable population of `size` modeled clients (see the module
/// docs). Constructed from [`Config::population`] plus the usual
/// system/train/dynamics sections; `system.clients` is ignored — the
/// cohort size takes its place.
pub struct Population {
    /// Template config (with `system.clients` = effective cohort).
    cfg: Config,
    /// Cohort-sized template scenario: carries everything K-independent
    /// (links, power budgets, workload profile, resolved dynamics);
    /// per-round views splice the cohort's sites/gains into a clone.
    template: Scenario,
    selector: Box<dyn Selector>,
    size: usize,
    /// Effective cohort `min(population.cohort, size)`.
    cohort: usize,
    deadline_drop: f64,
    /// `population.seed`: geometry + selection lifecycle.
    seed: u64,
    /// Static channel model (initial shadowing draw σ).
    model: ChannelModel,
    /// Resolved AR(1) parameters (dynamics σ is the resolved sentinel).
    sigma_dyn: f64,
    rho: f64,
    /// `sqrt(1 − ρ²)·σ_dyn`; 0 freezes the channel (no draws consumed).
    innovation_db: f64,
}

impl Population {
    pub fn new(cfg: &Config) -> Result<Population> {
        let p = &cfg.population;
        if p.size == 0 {
            bail!("population.size must be >= 1");
        }
        if p.cohort == 0 {
            bail!("population.cohort must be >= 1");
        }
        if !(0.0..1.0).contains(&p.deadline_drop) {
            bail!(
                "population.deadline_drop must be in [0, 1) — 1 would cut the whole \
                 cohort from every round — got {}",
                p.deadline_drop
            );
        }
        let selector = parse_selector(&p.selector).context("population.selector")?;
        let cohort = p.cohort.min(p.size);
        let mut tcfg = cfg.clone();
        tcfg.system.clients = cohort;
        // the builder validates everything a cohort view needs (cohort
        // <= subchannels, objective/dynamics specs) and resolves the
        // shadow-sigma inherit sentinel
        let template = ScenarioBuilder::from_config(tcfg.clone())
            .build()
            .with_context(|| format!("population template (cohort K = {cohort})"))?;
        let sigma_dyn = template.dynamics.shadow_sigma_db.max(0.0);
        let rho = template.dynamics.rho;
        let innovation_db = (1.0 - rho * rho).max(0.0).sqrt() * sigma_dyn;
        let model = ChannelModel::new(tcfg.system.shadowing_db);
        Ok(Population {
            size: p.size,
            cohort,
            deadline_drop: p.deadline_drop,
            seed: p.seed,
            selector,
            model,
            sigma_dyn,
            rho,
            innovation_db,
            cfg: tcfg,
            template,
        })
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Effective per-round cohort size (`min(population.cohort, size)`).
    pub fn cohort(&self) -> usize {
        self.cohort
    }

    pub fn deadline_drop(&self) -> f64 {
        self.deadline_drop
    }

    pub fn selector_label(&self) -> String {
        self.selector.label()
    }

    /// The cohort-sized template scenario (resolved dynamics,
    /// objective, links).
    pub fn template(&self) -> &Scenario {
        &self.template
    }

    /// A client's static draws: disk placement, compute capability, and
    /// initial shadowing — the same per-client quantities
    /// `Topology::sample` + `ChannelState::sample` draw, taken from the
    /// client's own [`stream`] instead of a shared sequential one.
    fn static_client(&self, i: usize) -> (ClientSite, f64, f64) {
        let s = &self.cfg.system;
        let mut rng = stream(self.seed, TAG_STATIC, i as u64, 0);
        // uniform over the disk: r = R*sqrt(u), fed server at origin,
        // main server at (d_main_m, 0) — Topology::sample's geometry
        let r = s.d_max_m * rng.f64().sqrt();
        let theta = rng.range(0.0, 2.0 * std::f64::consts::PI);
        let (x, y) = (r * theta.cos(), r * theta.sin());
        let d_fed = (x * x + y * y).sqrt().max(1.0);
        let dx = x - s.d_main_m;
        let d_main = (dx * dx + y * y).sqrt().max(1.0);
        let f = rng.range(s.f_client_lo, s.f_client_hi);
        let (sm, sf) = if s.shadowing_db > 0.0 {
            (
                rng.normal_ms(0.0, s.shadowing_db),
                rng.normal_ms(0.0, s.shadowing_db),
            )
        } else {
            (0.0, 0.0)
        };
        (
            ClientSite {
                d_main_m: d_main,
                d_fed_m: d_fed,
                f_cycles: f,
            },
            sm,
            sf,
        )
    }

    /// Observe client `i` at `round`, lazily materializing and
    /// advancing its state in O(1) regardless of how many rounds it was
    /// skipped (see the module docs for the closed forms). Observations
    /// per client must be monotone in `round`; re-observing the same
    /// round returns the cached state and consumes nothing.
    pub fn observe(&self, state: &mut PopulationState, i: usize, round: usize) -> Observation {
        assert!(i < self.size, "client {i} out of population (size {})", self.size);
        let slot = state.slots.entry(i).or_insert_with(|| {
            let (site, sm, sf) = self.static_client(i);
            ClientSlot {
                f_round: site.f_cycles,
                site,
                shadow_main_db: sm,
                shadow_fed_db: sf,
                online: true,
                last_round: 0,
            }
        });
        assert!(
            round >= slot.last_round,
            "population observations must be monotone per client \
             (client {i}: round {round} after round {})",
            slot.last_round
        );
        if round > slot.last_round {
            let gap = (round - slot.last_round) as u64;
            let d = &self.template.dynamics;
            let mut rng = stream(d.seed, TAG_OBSERVE, i as u64, round as u64);
            // draw order is fixed and config-gated (never value-gated),
            // so a knob toggles its own draws without shifting others'
            if self.innovation_db != 0.0 {
                let (rho_k, sigma_k) = ar1_jump(self.rho, self.sigma_dyn, gap);
                slot.shadow_main_db = rho_k * slot.shadow_main_db + rng.normal_ms(0.0, sigma_k);
                slot.shadow_fed_db = rho_k * slot.shadow_fed_db + rng.normal_ms(0.0, sigma_k);
            }
            if d.compute_jitter > 0.0 {
                // i.i.d. per round: only the observed round's draw counts
                slot.f_round = slot.site.f_cycles * (d.compute_jitter * rng.normal()).exp();
            }
            if d.dropout > 0.0 {
                // 2-state Markov chain advanced by its gap-step marginal
                let (p, q) = (d.dropout, d.rejoin);
                let pi = q / (p + q);
                let lam = 1.0 - p - q;
                let lam_k = lam.powi(gap.min(i32::MAX as u64) as i32);
                let s0 = if slot.online { 1.0 } else { 0.0 };
                slot.online = rng.f64() < pi + (s0 - pi) * lam_k;
            }
            slot.last_round = round;
        }
        let gm = db_to_linear(-(self.model.path_loss_db(slot.site.d_main_m) + slot.shadow_main_db));
        let gf = db_to_linear(-(self.model.path_loss_db(slot.site.d_fed_m) + slot.shadow_fed_db));
        Observation {
            d_main_m: slot.site.d_main_m,
            d_fed_m: slot.site.d_fed_m,
            f_cycles: slot.f_round,
            gain_main: gm,
            gain_fed: gf,
            online: slot.online,
        }
    }

    /// Select the round's cohort (sorted distinct ids, see
    /// [`Selector`]) from the round's counter-based stream, updating
    /// the invitation history. O(cohort) — except a one-time O(size)
    /// weight-index build for weight-proportional policies.
    pub fn select(&self, state: &mut PopulationState, round: usize) -> Vec<usize> {
        if self.selector.needs_weights() && state.weights.is_none() {
            state.weights = Some(WeightIndex::build(
                (0..self.size).map(|i| self.static_client(i).0.f_cycles),
            ));
        }
        let mut rng = stream(self.seed, TAG_SELECT, round as u64, 0);
        let mut out = Vec::with_capacity(self.cohort);
        {
            let ctx = SelectionCtx {
                size: self.size,
                cohort: self.cohort,
                round,
                weights: state.weights.as_ref(),
                last_invited: &state.last_invited,
            };
            self.selector.select(&ctx, &mut rng, &mut out);
        }
        for &i in &out {
            state.last_invited[i] = round.min(u32::MAX as usize - 1) as u32 + 1;
        }
        out
    }

    /// Splice a cohort's observations into a scenario view: the
    /// template with the cohort's sites, compute, and gains. Everything
    /// else (links, budgets, profile, dynamics) is K-independent.
    fn view_from(&self, obs: &[Observation]) -> Scenario {
        let mut scn = self.template.clone();
        scn.topo.clients = obs
            .iter()
            .map(|o| ClientSite {
                d_main_m: o.d_main_m,
                d_fed_m: o.d_fed_m,
                f_cycles: o.f_cycles,
            })
            .collect();
        scn.main_link.client_gain = obs.iter().map(|o| o.gain_main).collect();
        scn.fed_link.client_gain = obs.iter().map(|o| o.gain_fed).collect();
        scn
    }

    /// True when the per-client AR(1) channel never moves (ρ = 1 or
    /// σ = 0): sparse views then only drift through membership or
    /// compute jitter.
    pub(crate) fn channel_frozen(&self) -> bool {
        self.innovation_db == 0.0
    }

    /// Record an externally supplied cohort in the invitation history —
    /// the service's `cohort_selected` override performs exactly the
    /// bookkeeping [`Population::select`] performs, minus the draw
    /// (which is counter-based per round and simply left unconsumed).
    pub(crate) fn mark_invited(&self, state: &mut PopulationState, ids: &[usize], round: usize) {
        for &i in ids {
            state.last_invited[i] = round.min(u32::MAX as usize - 1) as u32 + 1;
        }
    }

    /// The round's scenario view and availability mask. Dense mode
    /// reads the evolved full-population environment; sparse mode
    /// observes exactly the cohort (O(cohort)). If every invitee is
    /// offline the round proceeds with the full cohort instead — the
    /// sparse analogue of the round simulator's empty-federation guard
    /// (per-client chain states are left untouched).
    pub(crate) fn round_view(
        &self,
        state: &mut PopulationState,
        denv: &mut Option<DriftEnv>,
        cohort: &[usize],
        round: usize,
    ) -> (Scenario, Vec<bool>) {
        if let Some(env) = denv {
            (env.scn.clone(), env.active.clone())
        } else {
            let obs: Vec<Observation> =
                cohort.iter().map(|&i| self.observe(state, i, round)).collect();
            let mut online: Vec<bool> = obs.iter().map(|o| o.online).collect();
            if !online.iter().any(|&a| a) {
                online = vec![true; online.len()];
            }
            (self.view_from(&obs), online)
        }
    }

    /// The full population lowered into one round-0 [`Scenario`] — only
    /// solvable when every client fits on a subchannel, i.e. for the
    /// degenerate populations the bit-identity anchor tests use (and
    /// the dense engine mode evolves).
    pub fn scenario(&self) -> Result<Scenario> {
        let s = &self.cfg.system;
        if self.size > s.subch_main || self.size > s.subch_fed {
            bail!(
                "a full-population scenario needs every client on a subchannel: \
                 {} clients exceed (M = {}, N = {})",
                self.size,
                s.subch_main,
                s.subch_fed
            );
        }
        let mut state = PopulationState::new(self.size);
        let obs: Vec<Observation> = (0..self.size).map(|i| self.observe(&mut state, i, 0)).collect();
        Ok(self.view_from(&obs))
    }
}

// Dense mode runs on `sim::engine::DriftEnv` — the exact shared-stream
// evolution `RoundSimulator::run` performs over the full population
// scenario (it *is* the same code since PR-8, which makes the
// degenerate-population anchor invariant structural rather than a
// transcription kept in sync by hand).

/// Re-communicate an incumbent allocation over a changed cohort: keep
/// the split decision `(l_c, rank)`, rebuild the subchannel assignment
/// (Algorithm 2) and the power PSDs (P2) for the new membership. The
/// incumbent's own assignment/power vectors index the *previous*
/// cohort's clients and are meaningless for the new one.
pub(crate) fn comm_alloc(view: &Scenario, l_c: usize, rank: usize) -> Result<Allocation> {
    let mut alloc = bcd::initial_alloc(view, l_c, rank);
    let p = power::solve_power(view, &alloc)
        .context("population run: re-communicating the incumbent over a changed cohort")?;
    alloc.psd_main = p.psd_main;
    alloc.psd_fed = p.psd_fed;
    Ok(alloc)
}

/// Straggler deadline: after the round's allocation is fixed, cut the
/// slowest `⌊deadline_drop · online⌋` cohort members (by realized
/// client-side phase delay) from the aggregate, masking them out of
/// `online` in place. Returns how many were cut. Shared statement for
/// statement by [`PopulationSimulator::run`] and the allocator
/// service's population tick.
pub(crate) fn deadline_cut(
    deadline_drop: f64,
    view: &Scenario,
    alloc: &Allocation,
    online: &mut [bool],
) -> usize {
    if deadline_drop <= 0.0 {
        return 0;
    }
    let online_count = online.iter().filter(|&&a| a).count();
    let cut = ((deadline_drop * online_count as f64).floor() as usize)
        .min(online_count.saturating_sub(1));
    if cut == 0 {
        return 0;
    }
    let pd = view.phase_delays(alloc);
    let mut times: Vec<(usize, f64)> = online
        .iter()
        .enumerate()
        .filter(|&(_, &a)| a)
        .map(|(k, _)| {
            (
                k,
                pd.client_fwd[k] + pd.act_upload[k] + pd.client_bwd[k] + pd.fed_upload[k],
            )
        })
        .collect();
    // slowest first; ties broken by id for determinism. total_cmp:
    // phase delays are non-negative sums (possibly +inf), never NaN,
    // so this matches the old partial_cmp order minus the Equal
    // fallback
    times.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    for &(k, _) in times.iter().take(cut) {
        online[k] = false;
    }
    cut
}

/// Plays a fine-tuning run out over a [`Population`]: per-round cohort
/// selection, lazy observation, O(cohort) solves/evaluation, straggler
/// deadlines, and the same progress/run-length accounting as
/// [`crate::sim::RoundSimulator`] (whose records and outcome type it
/// reuses).
pub struct PopulationSimulator<'a> {
    pop: &'a Population,
    conv: &'a ConvergenceModel,
    cache: &'a WorkloadCache,
    ranks: Vec<usize>,
}

impl<'a> PopulationSimulator<'a> {
    /// `ranks` is the candidate rank set shared with the policies being
    /// simulated, so evaluator builds hit the same cached table.
    pub fn new(
        pop: &'a Population,
        conv: &'a ConvergenceModel,
        cache: &'a WorkloadCache,
        ranks: &[usize],
    ) -> PopulationSimulator<'a> {
        assert!(!ranks.is_empty(), "empty candidate rank set");
        PopulationSimulator {
            pop,
            conv,
            cache,
            ranks: ranks.to_vec(),
        }
    }

    /// Simulate one full run of `policy` under `strategy` (see
    /// [`crate::sim::RoundSimulator::run`] for the shared accounting
    /// semantics; this engine adds selection, deadlines, and cohort
    /// rebasing).
    pub fn run(
        &self,
        policy: &dyn AllocationPolicy,
        strategy: ReOptStrategy,
    ) -> Result<DynamicOutcome> {
        self.run_faulted(policy, strategy, &FaultPlan::default())
    }

    /// [`PopulationSimulator::run`] under a fault plan (PR-10). The
    /// overlay indexes the round's *view* (cohort positions, not
    /// population ids), and since both engine modes hand back per-round
    /// clones from [`Population::round_view`], it is applied to the
    /// clone directly — no undo pass; the only cross-round residue is
    /// an `env_dirty` mark so the drift memo never serves a faulted
    /// solve to a clean round. An empty plan executes exactly `run`'s
    /// statements, keeping fault-free runs bit-identical.
    pub fn run_faulted(
        &self,
        policy: &dyn AllocationPolicy,
        strategy: ReOptStrategy,
        plan: &FaultPlan,
    ) -> Result<DynamicOutcome> {
        let pop = self.pop;
        let dynamics = pop.template.dynamics.clone();
        let dense = pop.cohort >= pop.size;
        let objective = Objective::from_config(&pop.template.objective)?;
        let table: Arc<WorkloadTable> = self.cache.table_for(&pop.template.profile, &self.ranks);
        let frozen_channel = pop.innovation_db == 0.0;
        let injector = if plan.is_empty() {
            None
        } else {
            plan.validate()?;
            Some(FaultInjector::new(plan.clone()))
        };

        let mut state = PopulationState::new(pop.size);
        let mut denv: Option<DriftEnv> = if dense {
            Some(DriftEnv::new(pop.scenario()?))
        } else {
            None
        };

        // --- round 0: invite, observe, solve on the initial view
        let mut cur_cohort = pop.select(&mut state, 0);
        let (mut cur_view, mut online) = pop.round_view(&mut state, &mut denv, &cur_cohort, 0);
        let out0 = policy
            .solve_cached(&cur_view, self.conv, self.cache)
            .context("population run: round-0 solve")?;
        let static_prediction = cur_view.total_delay(&out0.alloc, self.conv);
        let mut core = RoundCore::new(out0.alloc, static_prediction, self.conv);
        let ctx = StepCtx {
            conv: self.conv,
            cache: self.cache,
            table: &table,
            objective: &objective,
            strategy,
            ranks: &self.ranks,
            label: "population",
        };

        while !core.done() {
            core.check_cap(dynamics.max_rounds, &ctx)?;
            let mut resolved = core.round == 0;
            let mut cost_round: Option<RoundCost> = None;
            let mut dropped = 0usize;
            let mut faults = 0usize;
            let mut repair_tier = 0u8;
            let mut shed: Vec<usize> = Vec::new();
            if core.round > 0 {
                // --- evolve the environment and lower the new cohort
                if let Some(env) = denv.as_mut() {
                    if env.advance() {
                        core.env_dirty = true;
                    }
                }
                let cohort = pop.select(&mut state, core.round);
                let cohort_changed = cohort != cur_cohort;
                let (view, on) = pop.round_view(&mut state, &mut denv, &cohort, core.round);
                cur_view = view;
                online = on;
                if denv.is_none() {
                    // a sparse view is rebuilt from fresh observations:
                    // it drifts whenever the membership, the channel,
                    // or the compute can have moved
                    core.env_dirty |=
                        cohort_changed || !frozen_channel || dynamics.compute_jitter > 0.0;
                }
                cur_cohort = cohort;
                if cohort_changed {
                    // once the cohort has changed, the round-0
                    // allocation indexes clients that are no longer in
                    // the view — rebasing retires it as a re-adoption
                    // candidate for good (on the clean view: rebasing is
                    // membership bookkeeping, not a reaction to faults)
                    let rebased = comm_alloc(&cur_view, core.alloc.l_c, core.alloc.rank)?;
                    core.rebase_incumbent(rebased);
                }
                if let Some(inj) = &injector {
                    let ov = inj.overlay(core.round, cur_view.k());
                    if !ov.is_empty() {
                        faults = ov.count();
                        core.faults_injected += faults;
                        apply_to_scenario(&mut cur_view, &ov);
                        if !ov.crashed.is_empty() {
                            let prev = online.clone();
                            for &k in &ov.crashed {
                                if let Some(a) = online.get_mut(k) {
                                    *a = false;
                                }
                            }
                            if !online.iter().any(|&a| a) {
                                // never simulate an empty federation
                                online = prev;
                            }
                        }
                        core.env_dirty = true;
                    }
                }
                let re = core.maybe_reopt(&ctx, policy, &cur_view, &online)?;
                resolved = re.resolved;
                cost_round = re.cost;
                repair_tier = re.repair_tier;
                shed = re.shed;
            }

            if !shed.is_empty() {
                // tier-3 repair: shed clients sit the round out (their
                // allocation rows are empty — scoring them active, or
                // ranking them for the deadline, would be infinite)
                for &k in &shed {
                    if let Some(a) = online.get_mut(k) {
                        *a = false;
                    }
                }
                if !online.iter().any(|&a| a) {
                    // never realize an empty federation: the kept
                    // clients participate even if the availability chain
                    // had them offline this round
                    for (k, a) in online.iter_mut().enumerate() {
                        *a = !shed.contains(&k);
                    }
                }
            }

            // --- straggler deadline: cut the slowest ⌊x·online⌋ cohort
            // members by realized client-side phase delay
            let cut = deadline_cut(pop.deadline_drop, &cur_view, &core.alloc, &mut online);
            if cut > 0 {
                dropped = cut;
                core.deadline_drops += cut;
                // any cost computed above used the pre-deadline mask
                cost_round = None;
            }

            core.realize(
                &ctx,
                &cur_view,
                &online,
                cost_round,
                resolved,
                cur_cohort.len(),
                dropped,
                faults,
                repair_tier,
            );
            if faults > 0 {
                // the view clone dies with the round, but the drift memo
                // must not serve this round's faulted solve to the next,
                // clean one
                core.env_dirty = true;
            }
        }

        let unique_participants = if dense { pop.size } else { state.materialized() };
        Ok(core.finish(unique_participants))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::policy::Proposed;
    use crate::sim::RoundSimulator;

    const RANKS: [usize; 2] = [1, 4];

    fn small_conv() -> ConvergenceModel {
        ConvergenceModel::fitted(4.0, 1.0, 0.85)
    }

    fn pop_config(size: usize, cohort: usize, selector: &str) -> Config {
        let mut cfg = Config::paper_defaults();
        cfg.model = "tiny".to_string();
        cfg.train.seq = 64;
        cfg.train.ranks = vec![1, 4];
        cfg.system.subch_main = 16;
        cfg.system.subch_fed = 16;
        cfg.population.size = size;
        cfg.population.cohort = cohort;
        cfg.population.selector = selector.to_string();
        cfg.population.deadline_drop = 0.0;
        cfg.population.seed = 5;
        cfg.dynamics.rho = 0.8;
        cfg.dynamics.seed = 11;
        cfg
    }

    #[test]
    fn degenerate_population_reproduces_round_simulator_bit_for_bit() {
        // population == K, full-participation selection, no deadline:
        // the anchor invariant, including jitter and dropout
        let mut cfg = pop_config(4, 4, "uniform");
        cfg.dynamics.compute_jitter = 0.05;
        cfg.dynamics.dropout = 0.1;
        cfg.dynamics.rejoin = 0.4;
        let pop = Population::new(&cfg).unwrap();
        let scn = pop.scenario().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        for strat in [ReOptStrategy::OneShot, ReOptStrategy::Periodic(2)] {
            let rs = RoundSimulator::new(&scn, &conv, &cache, &RANKS)
                .run(&policy, strat)
                .unwrap();
            let ps = PopulationSimulator::new(&pop, &conv, &cache, &RANKS)
                .run(&policy, strat)
                .unwrap();
            assert_eq!(ps.realized_delay.to_bits(), rs.realized_delay.to_bits());
            assert_eq!(ps.realized_energy.to_bits(), rs.realized_energy.to_bits());
            assert_eq!(ps.static_prediction.to_bits(), rs.static_prediction.to_bits());
            assert_eq!(ps.resolves, rs.resolves);
            assert_eq!(ps.fresh_solves, rs.fresh_solves);
            assert_eq!(ps.rounds.len(), rs.rounds.len());
            for (a, b) in ps.rounds.iter().zip(&rs.rounds) {
                assert_eq!(a.delay.to_bits(), b.delay.to_bits(), "round {}", a.round);
                assert_eq!(a.energy.to_bits(), b.energy.to_bits());
                assert_eq!(a.weight.to_bits(), b.weight.to_bits());
                assert_eq!((a.l_c, a.rank, a.active, a.resolved), (b.l_c, b.rank, b.active, b.resolved));
                assert_eq!(a.cohort, 4);
                assert_eq!(a.dropped, 0);
            }
            assert_eq!(ps.unique_participants, 4);
            assert_eq!(ps.deadline_drops, 0);
        }
    }

    #[test]
    fn lazy_observation_matches_eager_per_round_stepping_bit_for_bit() {
        // observing every round produces gap-1 jumps, which must carry
        // the exact bits of the eager AR(1) recursion (the ar1_jump
        // exactness contract lifted to the population level)
        let cfg = pop_config(50, 8, "uniform");
        let pop = Population::new(&cfg).unwrap();
        let mut state = PopulationState::new(pop.size());
        let i = 7usize;
        let (site, mut sm, mut sf) = pop.static_client(i);
        let d_seed = pop.template().dynamics.seed;
        for r in 1..=10usize {
            let mut rng = stream(d_seed, TAG_OBSERVE, i as u64, r as u64);
            sm = pop.rho * sm + rng.normal_ms(0.0, pop.innovation_db);
            sf = pop.rho * sf + rng.normal_ms(0.0, pop.innovation_db);
            let obs = pop.observe(&mut state, i, r);
            let want_gm = db_to_linear(-(pop.model.path_loss_db(site.d_main_m) + sm));
            let want_gf = db_to_linear(-(pop.model.path_loss_db(site.d_fed_m) + sf));
            assert_eq!(obs.gain_main.to_bits(), want_gm.to_bits(), "round {r}");
            assert_eq!(obs.gain_fed.to_bits(), want_gf.to_bits(), "round {r}");
            assert_eq!(obs.f_cycles.to_bits(), site.f_cycles.to_bits(), "no jitter configured");
            assert!(obs.online);
        }
    }

    #[test]
    fn observation_is_independent_of_other_clients_schedules() {
        let mut cfg = pop_config(100, 8, "uniform");
        cfg.dynamics.compute_jitter = 0.1;
        cfg.dynamics.dropout = 0.15;
        cfg.dynamics.rejoin = 0.5;
        let pop = Population::new(&cfg).unwrap();
        let mut a = PopulationState::new(pop.size());
        let mut b = PopulationState::new(pop.size());
        // b carries heavy unrelated traffic before client 3 is touched
        for r in 1..=5usize {
            for i in [0usize, 1, 2, 4, 9, 17, 63, 99] {
                pop.observe(&mut b, i, r);
            }
        }
        for r in [2usize, 5] {
            let oa = pop.observe(&mut a, 3, r);
            let ob = pop.observe(&mut b, 3, r);
            assert_eq!(oa.gain_main.to_bits(), ob.gain_main.to_bits(), "round {r}");
            assert_eq!(oa.gain_fed.to_bits(), ob.gain_fed.to_bits(), "round {r}");
            assert_eq!(oa.f_cycles.to_bits(), ob.f_cycles.to_bits(), "round {r}");
            assert_eq!(oa.online, ob.online, "round {r}");
        }
    }

    #[test]
    fn round_records_are_independent_of_slot_insertion_history() {
        // the slot map must not leak materialization history:
        // observing clients in any order yields bit-identical
        // per-round observations and a sorted iteration order
        let mut cfg = pop_config(80, 8, "uniform");
        cfg.dynamics.compute_jitter = 0.1;
        cfg.dynamics.dropout = 0.1;
        cfg.dynamics.rejoin = 0.4;
        let pop = Population::new(&cfg).unwrap();
        let ids = [5usize, 63, 0, 41, 12, 79, 3];
        let mut fwd = PopulationState::new(pop.size());
        let mut rev = PopulationState::new(pop.size());
        for &i in &ids {
            pop.observe(&mut fwd, i, 4);
        }
        for &i in ids.iter().rev() {
            pop.observe(&mut rev, i, 4);
        }
        for r in 5..=7usize {
            for &i in &ids {
                let a = pop.observe(&mut fwd, i, r);
                let b = pop.observe(&mut rev, i, r);
                assert_eq!(a.gain_main.to_bits(), b.gain_main.to_bits(), "client {i} round {r}");
                assert_eq!(a.gain_fed.to_bits(), b.gain_fed.to_bits(), "client {i} round {r}");
                assert_eq!(a.f_cycles.to_bits(), b.f_cycles.to_bits(), "client {i} round {r}");
                assert_eq!(a.online, b.online, "client {i} round {r}");
            }
        }
        // iteration order is by client id, not by materialization order
        let fwd_keys: Vec<usize> = fwd.slots.keys().copied().collect();
        let rev_keys: Vec<usize> = rev.slots.keys().copied().collect();
        assert!(fwd_keys.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(fwd_keys, rev_keys);
    }

    #[test]
    fn gap_jumps_are_deterministic_and_cached_within_a_round() {
        let mut cfg = pop_config(40, 8, "uniform");
        cfg.dynamics.compute_jitter = 0.1;
        let pop = Population::new(&cfg).unwrap();
        let one_jump = |round: usize| {
            let mut s = PopulationState::new(pop.size());
            pop.observe(&mut s, 11, round)
        };
        let x = one_jump(10);
        let y = one_jump(10);
        assert_eq!(x.gain_main.to_bits(), y.gain_main.to_bits());
        assert_eq!(x.f_cycles.to_bits(), y.f_cycles.to_bits());
        // re-observing the same round is served from the slot
        let mut s = PopulationState::new(pop.size());
        let first = pop.observe(&mut s, 11, 10);
        let again = pop.observe(&mut s, 11, 10);
        assert_eq!(first.gain_main.to_bits(), again.gain_main.to_bits());
        assert_eq!(first.f_cycles.to_bits(), again.f_cycles.to_bits());
        assert_eq!(s.materialized(), 1);
    }

    #[test]
    fn staleness_selection_spreads_participation_deterministically() {
        let cfg = pop_config(60, 10, "staleness:2");
        let pop = Population::new(&cfg).unwrap();
        let run = || {
            let mut state = PopulationState::new(pop.size());
            (0..3).map(|r| pop.select(&mut state, r)).collect::<Vec<_>>()
        };
        let rounds = run();
        assert_eq!(rounds, run(), "selection must be reproducible");
        for w in rounds.windows(2) {
            assert!(
                w[1].iter().all(|i| !w[0].contains(i)),
                "tau = 2 must keep consecutive cohorts disjoint: {:?} then {:?}",
                w[0],
                w[1]
            );
        }
        for c in &rounds {
            assert_eq!(c.len(), 10);
            assert!(c.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn straggler_deadline_drops_slowest_and_accounts() {
        let mut cfg = pop_config(40, 10, "uniform");
        cfg.dynamics.rho = 1.0; // frozen channel isolates the deadline
        cfg.population.deadline_drop = 0.25;
        let pop = Population::new(&cfg).unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let out = PopulationSimulator::new(&pop, &conv, &cache, &RANKS)
            .run(&policy, ReOptStrategy::OneShot)
            .unwrap();
        for r in &out.rounds {
            assert_eq!(r.cohort, 10);
            assert_eq!(r.dropped, 2, "floor(0.25 * 10) stragglers per round");
            assert_eq!(r.active, 8);
        }
        assert_eq!(out.deadline_drops, 2 * out.rounds.len());

        // cutting the slowest clients can only help the realized delay
        let mut cfg_nd = cfg.clone();
        cfg_nd.population.deadline_drop = 0.0;
        let pop_nd = Population::new(&cfg_nd).unwrap();
        let base = PopulationSimulator::new(&pop_nd, &conv, &cache, &RANKS)
            .run(&policy, ReOptStrategy::OneShot)
            .unwrap();
        assert!(out.realized_delay <= base.realized_delay);
        assert_eq!(base.deadline_drops, 0);
    }

    #[test]
    fn sparse_runs_are_deterministic_and_track_participation() {
        let mut cfg = pop_config(300, 8, "staleness:3");
        cfg.dynamics.compute_jitter = 0.05;
        cfg.dynamics.dropout = 0.1;
        cfg.dynamics.rejoin = 0.4;
        let pop = Population::new(&cfg).unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let sim = PopulationSimulator::new(&pop, &conv, &cache, &RANKS);
        let a = sim.run(&policy, ReOptStrategy::Periodic(3)).unwrap();
        let b = sim.run(&policy, ReOptStrategy::Periodic(3)).unwrap();
        assert_eq!(a.realized_delay.to_bits(), b.realized_delay.to_bits());
        assert_eq!(a.realized_energy.to_bits(), b.realized_energy.to_bits());
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.delay.to_bits(), y.delay.to_bits());
            assert_eq!(x.active, y.active);
            assert_eq!(x.cohort, 8);
        }
        // staleness rotation reaches deep into the population, but the
        // engine only ever materializes what it observed
        assert!(a.unique_participants > 8, "{}", a.unique_participants);
        assert!(a.unique_participants <= 300);
        assert!(a.fresh_solves > 0, "drifting sparse views must re-solve");
    }

    #[test]
    fn empty_fault_plan_is_bit_transparent_for_populations() {
        let mut cfg = pop_config(300, 8, "staleness:3");
        cfg.dynamics.compute_jitter = 0.05;
        cfg.dynamics.dropout = 0.1;
        cfg.dynamics.rejoin = 0.4;
        let pop = Population::new(&cfg).unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let sim = PopulationSimulator::new(&pop, &conv, &cache, &RANKS);
        let plain = sim.run(&policy, ReOptStrategy::Periodic(2)).unwrap();
        let faulted = sim
            .run_faulted(&policy, ReOptStrategy::Periodic(2), &FaultPlan::default())
            .unwrap();
        assert_eq!(faulted.faults_injected, 0);
        assert_eq!(faulted.repair_max, 0);
        assert_eq!(plain.realized_delay.to_bits(), faulted.realized_delay.to_bits());
        assert_eq!(plain.realized_energy.to_bits(), faulted.realized_energy.to_bits());
        for (x, y) in plain.rounds.iter().zip(&faulted.rounds) {
            assert_eq!(x.delay.to_bits(), y.delay.to_bits());
            assert_eq!(y.faults, 0);
        }
    }

    #[test]
    fn population_fault_runs_replay_identically_and_stay_finite() {
        let mut cfg = pop_config(120, 8, "uniform");
        cfg.dynamics.dropout = 0.05;
        cfg.dynamics.rejoin = 0.5;
        let pop = Population::new(&cfg).unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let sim = PopulationSimulator::new(&pop, &conv, &cache, &RANKS);
        let plan = FaultPlan::parse("crash=0.3,stall=0.3:0.5,outage=0.3:0,seed=7").unwrap();
        let a = sim
            .run_faulted(&policy, ReOptStrategy::EveryRound, &plan)
            .unwrap();
        assert!(a.faults_injected > 0, "30% rates on an 8-cohort never fired");
        assert!(a.realized_delay.is_finite(), "degradation must stay finite");
        assert!(a.rounds.iter().all(|r| r.active >= 1), "empty federation simulated");
        let b = sim
            .run_faulted(&policy, ReOptStrategy::EveryRound, &plan)
            .unwrap();
        assert_eq!(a.realized_delay.to_bits(), b.realized_delay.to_bits());
        assert_eq!(a.faults_injected, b.faults_injected);
        assert_eq!(a.repair_max, b.repair_max);
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.faults, y.faults);
            assert_eq!(x.repair_tier, y.repair_tier);
            assert_eq!(x.active, y.active);
            assert_eq!(x.delay.to_bits(), y.delay.to_bits());
        }
    }

    #[test]
    fn weighted_selection_builds_the_index_lazily() {
        let cfg = pop_config(200, 8, "weighted");
        let pop = Population::new(&cfg).unwrap();
        let mut state = PopulationState::new(pop.size());
        assert!(state.weights.is_none());
        let cohort = pop.select(&mut state, 0);
        assert!(state.weights.is_some(), "weighted selector must build the index");
        assert_eq!(cohort.len(), 8);
        // and a full run goes through
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let out = PopulationSimulator::new(&pop, &conv, &cache, &RANKS)
            .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::OneShot)
            .unwrap();
        assert!(out.realized_delay.is_finite() && out.realized_delay > 0.0);
    }

    #[test]
    fn invalid_population_configs_are_rejected_descriptively() {
        let mut cfg = pop_config(100, 8, "uniform");
        cfg.population.size = 0;
        assert!(Population::new(&cfg).is_err());
        let mut cfg = pop_config(100, 8, "uniform");
        cfg.population.cohort = 0;
        assert!(Population::new(&cfg).is_err());
        let mut cfg = pop_config(100, 8, "uniform");
        cfg.population.deadline_drop = 1.0;
        let err = format!("{:#}", Population::new(&cfg).unwrap_err());
        assert!(err.contains("deadline_drop"), "{err}");
        let mut cfg = pop_config(100, 8, "uniform");
        cfg.population.selector = "typo".to_string();
        let err = format!("{:#}", Population::new(&cfg).unwrap_err());
        assert!(err.contains("uniform") && err.contains("staleness"), "{err}");
        // cohort must fit on the subchannels (validated by the template)
        let mut cfg = pop_config(100, 8, "uniform");
        cfg.population.cohort = 17; // subch = 16
        let err = format!("{:#}", Population::new(&cfg).unwrap_err());
        assert!(err.contains("subchannel"), "{err}");
    }

    #[test]
    fn full_population_scenario_requires_subchannel_coverage() {
        let cfg = pop_config(100, 8, "uniform"); // 100 > 16 subchannels
        let pop = Population::new(&cfg).unwrap();
        let err = format!("{:#}", pop.scenario().unwrap_err());
        assert!(err.contains("subchannel"), "{err}");
        let small = Population::new(&pop_config(12, 4, "uniform")).unwrap();
        let scn = small.scenario().unwrap();
        assert_eq!(scn.k(), 12);
        assert!(scn.main_link.client_gain.iter().all(|&g| g > 0.0));
    }
}
