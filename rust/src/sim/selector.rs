//! Per-round cohort selection over a client population.
//!
//! The population engine ([`crate::sim::population`]) invites a small
//! cohort (16–256 clients) out of 10^5–10^6 modeled clients each round;
//! this module is the pluggable policy deciding *who*. The contract
//! ([`Selector`]) is deliberately narrow so per-round selection cost is
//! O(cohort), independent of population size:
//!
//! * selection sees only a [`SelectionCtx`] — population size, target
//!   cohort, round index, invitation history, and (for weighted
//!   policies) a prebuilt prefix-sum [`WeightIndex`] — never the
//!   per-client channel/compute state, which stays lazily materialized;
//! * the RNG handed in is a **counter-based per-round stream** (a pure
//!   function of `(population seed, round)`, see
//!   `population::stream`), so the cohort of round `e` is
//!   independent of call order, thread placement, and whether earlier
//!   rounds were ever selected — checkpoint/resume reproduces it
//!   bit for bit;
//! * the returned cohort is distinct client ids **sorted ascending**
//!   (the canonical order the degenerate-population bit-identity
//!   invariant and thread-invariance tests rely on);
//! * selection is availability-blind: invitees may turn out to be
//!   offline (no-shows are masked out by the simulator, mirroring
//!   xaynet's invite-then-wait coordinator lifecycle).
//!
//! Three policies, spec-addressable for CLI/config
//! ([`parse_selector`]): `uniform`, `weighted` (invitation probability
//! ∝ compute capability `f_k`), and `staleness:<τ>` (uniform over
//! clients not invited within the last τ rounds, with a deterministic
//! fallback when the fresh pool runs dry).

// lint:allow(D001) membership-only rejection-sampling sets below; never iterated
use std::collections::HashSet;

use anyhow::{anyhow, bail, Result};

use crate::util::rng::Rng;

/// Everything a [`Selector`] may consult for one round's cohort.
pub struct SelectionCtx<'a> {
    /// Population size P.
    pub size: usize,
    /// Target cohort size C (>= 1; C >= P selects everyone).
    pub cohort: usize,
    /// Round index the cohort is being selected for.
    pub round: usize,
    /// Prefix-sum sampling index over per-client weights; built once
    /// (O(P)) by the population, and only when
    /// [`Selector::needs_weights`] asks for it.
    pub weights: Option<&'a WeightIndex>,
    /// Per-client last-invited round, encoded `round + 1` (0 = never
    /// invited). `u32` keeps the history at 4 bytes/client for 10^6
    /// clients.
    pub last_invited: &'a [u32],
}

/// A cohort-selection policy. See the module docs for the contract
/// (distinct sorted ids, O(cohort) per round, counter-based RNG).
pub trait Selector: Send + Sync {
    /// The spec string [`parse_selector`] round-trips.
    fn label(&self) -> String;

    /// Whether [`SelectionCtx::weights`] must be populated. Building
    /// the index costs O(P) once per run; policies that never read it
    /// keep the population fully lazy.
    fn needs_weights(&self) -> bool {
        false
    }

    /// Fill `out` with the round's cohort: `min(cohort, size)` distinct
    /// client ids in ascending order.
    fn select(&self, ctx: &SelectionCtx, rng: &mut Rng, out: &mut Vec<usize>);
}

/// Parse a CLI/config selector spec: `uniform`, `weighted`,
/// `staleness:<τ>` (τ >= 1 rounds). Descriptive `Err`, never panics.
pub fn parse_selector(spec: &str) -> Result<Box<dyn Selector>> {
    let spec = spec.trim();
    let (head, arg) = match spec.split_once(':') {
        Some((h, a)) => (h.trim(), Some(a.trim())),
        None => (spec, None),
    };
    Ok(match (head, arg) {
        ("uniform", None) => Box::new(Uniform),
        ("weighted", None) => Box::new(WeightProportional),
        ("staleness", Some(a)) => {
            let tau: usize = a
                .parse()
                .map_err(|e| anyhow!("bad staleness window '{a}': {e}"))?;
            if tau == 0 {
                bail!("staleness window must be >= 1 round (0 would be exactly `uniform`)");
            }
            Box::new(StalenessAware(tau))
        }
        _ => bail!(
            "unknown selector '{spec}' \
             (available: uniform, weighted, staleness:<tau>)"
        ),
    })
}

/// Prefix-sum index for weight-proportional sampling: one O(P) build,
/// O(log P) per draw (binary search on the cumulative weight).
#[derive(Clone, Debug)]
pub struct WeightIndex {
    /// `prefix[i]` = sum of weights `0..i`; `prefix[P]` is the total.
    prefix: Vec<f64>,
}

impl WeightIndex {
    /// Build from per-client weights (must be finite and > 0 — the
    /// population uses compute capability `f_k`, which always is).
    pub fn build<I: Iterator<Item = f64>>(weights: I) -> WeightIndex {
        let mut prefix = vec![0.0];
        let mut acc = 0.0f64;
        for w in weights {
            acc += w.max(0.0);
            prefix.push(acc);
        }
        WeightIndex { prefix }
    }

    pub fn len(&self) -> usize {
        self.prefix.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Draw one client id with probability ∝ its weight.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        // lint:allow(P101) prefix is constructed as vec![0.0] + pushes, never empty
        let total = *self.prefix.last().unwrap();
        let u = rng.f64() * total;
        // first i with prefix[i+1] > u
        match self
            .prefix
            .partition_point(|&p| p <= u)
        {
            0 => 0,
            i => (i - 1).min(self.len() - 1),
        }
    }
}

/// Uniform sampling without replacement (rejection on a `HashSet`;
/// cohorts are far smaller than the population, so collisions are
/// rare).
pub struct Uniform;

impl Selector for Uniform {
    fn label(&self) -> String {
        "uniform".to_string()
    }

    fn select(&self, ctx: &SelectionCtx, rng: &mut Rng, out: &mut Vec<usize>) {
        out.clear();
        if ctx.cohort >= ctx.size {
            out.extend(0..ctx.size);
            return;
        }
        // lint:allow(D001) membership test only (insert + contains); iteration order unused
        let mut taken = HashSet::with_capacity(ctx.cohort);
        while out.len() < ctx.cohort {
            let i = rng.below(ctx.size);
            if taken.insert(i) {
                out.push(i);
            }
        }
        out.sort_unstable();
    }
}

/// Invitation probability ∝ compute capability `f_k` (fast clients are
/// invited more often — the capacity-weighted regime heterogeneous
/// split-fed deployments run). Pays one O(P) [`WeightIndex`] build for
/// the whole run, then O(C log P) per round.
pub struct WeightProportional;

impl Selector for WeightProportional {
    fn label(&self) -> String {
        "weighted".to_string()
    }

    fn needs_weights(&self) -> bool {
        true
    }

    fn select(&self, ctx: &SelectionCtx, rng: &mut Rng, out: &mut Vec<usize>) {
        out.clear();
        if ctx.cohort >= ctx.size {
            out.extend(0..ctx.size);
            return;
        }
        // lint:allow(P101) needs_weights() contract: the harness always supplies weights here
        let idx = ctx.weights.expect("WeightProportional requires SelectionCtx::weights");
        // lint:allow(D001) membership test only (insert + contains); iteration order unused
        let mut taken = HashSet::with_capacity(ctx.cohort);
        while out.len() < ctx.cohort {
            let i = idx.sample(rng);
            if taken.insert(i) {
                out.push(i);
            }
        }
        out.sort_unstable();
    }
}

/// Uniform over clients **not** invited within the last τ rounds —
/// spreads participation across the population (xaynet's
/// once-per-epoch selection generalized to a sliding window).
///
/// Two-pass with a deterministic fallback: rejected-as-recent
/// candidates are remembered in draw order and used to fill the cohort
/// if the fresh pool runs dry (small populations, large cohorts); a
/// final id-order sweep guarantees the exact cohort size in every
/// case. All three passes are pure functions of the RNG stream, so the
/// cohort stays reproducible.
pub struct StalenessAware(pub usize);

impl Selector for StalenessAware {
    fn label(&self) -> String {
        format!("staleness:{}", self.0)
    }

    fn select(&self, ctx: &SelectionCtx, rng: &mut Rng, out: &mut Vec<usize>) {
        out.clear();
        if ctx.cohort >= ctx.size {
            out.extend(0..ctx.size);
            return;
        }
        let tau = self.0;
        // invited at round e' (= last_invited - 1), recent iff the
        // current round is within (e', e' + tau]
        let recent = |i: usize| -> bool {
            match ctx.last_invited[i] {
                0 => false,
                li => ctx.round <= (li as usize - 1) + tau,
            }
        };
        // lint:allow(D001) membership test only (insert + contains); iteration order unused
        let mut taken = HashSet::with_capacity(ctx.cohort);
        let mut fallback: Vec<usize> = Vec::new();
        let max_attempts = 16 * ctx.cohort + 64;
        let mut attempts = 0;
        while out.len() < ctx.cohort && attempts < max_attempts {
            attempts += 1;
            let i = rng.below(ctx.size);
            if taken.contains(&i) {
                continue;
            }
            if recent(i) {
                if !fallback.contains(&i) {
                    fallback.push(i);
                }
                continue;
            }
            taken.insert(i);
            out.push(i);
        }
        for i in fallback {
            if out.len() >= ctx.cohort {
                break;
            }
            if taken.insert(i) {
                out.push(i);
            }
        }
        let mut i = 0;
        while out.len() < ctx.cohort {
            if taken.insert(i) {
                out.push(i);
            }
            i += 1;
        }
        out.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx<'a>(
        size: usize,
        cohort: usize,
        round: usize,
        weights: Option<&'a WeightIndex>,
        last_invited: &'a [u32],
    ) -> SelectionCtx<'a> {
        SelectionCtx { size, cohort, round, weights, last_invited }
    }

    #[test]
    fn specs_round_trip_and_reject_garbage() {
        for spec in ["uniform", "weighted", "staleness:5"] {
            let s = parse_selector(spec).unwrap();
            assert_eq!(s.label(), spec);
            assert_eq!(parse_selector(&s.label()).unwrap().label(), spec);
        }
        assert_eq!(parse_selector("  staleness: 3 ").unwrap().label(), "staleness:3");
        for bad in [
            "nope",
            "staleness",
            "staleness:0",
            "staleness:x",
            "staleness:-1",
            "uniform:2",
            "weighted:1",
            "",
        ] {
            let err = parse_selector(bad);
            assert!(err.is_err(), "'{bad}' should fail");
            let msg = format!("{:#}", err.unwrap_err());
            assert!(!msg.is_empty());
        }
        // the catalog is in the unknown-spec error
        let msg = format!("{:#}", parse_selector("typo").unwrap_err());
        assert!(msg.contains("uniform") && msg.contains("staleness"), "{msg}");
    }

    #[test]
    fn cohorts_are_distinct_sorted_and_exactly_sized() {
        let none: [u32; 0] = [];
        let hist = vec![0u32; 1000];
        let widx = WeightIndex::build((0..1000).map(|i| 1.0 + i as f64));
        let selectors: Vec<Box<dyn Selector>> = vec![
            Box::new(Uniform),
            Box::new(WeightProportional),
            Box::new(StalenessAware(4)),
        ];
        let _ = none;
        for s in &selectors {
            for round in 0..5 {
                let mut rng = Rng::new(900 + round as u64);
                let mut out = Vec::new();
                s.select(&ctx(1000, 64, round, Some(&widx), &hist), &mut rng, &mut out);
                assert_eq!(out.len(), 64, "{}", s.label());
                assert!(out.windows(2).all(|w| w[0] < w[1]), "{} not sorted-distinct", s.label());
                assert!(out.iter().all(|&i| i < 1000), "{}", s.label());
            }
        }
    }

    #[test]
    fn full_participation_when_cohort_covers_the_population() {
        let hist = vec![7u32; 12]; // even "all recent" must yield everyone
        let widx = WeightIndex::build((0..12).map(|_| 1.0));
        let selectors: Vec<Box<dyn Selector>> = vec![
            Box::new(Uniform),
            Box::new(WeightProportional),
            Box::new(StalenessAware(3)),
        ];
        for s in &selectors {
            for cohort in [12, 20] {
                let mut rng = Rng::new(1);
                let before = rng.clone().next_u64();
                let mut out = Vec::new();
                s.select(&ctx(12, cohort, 9, Some(&widx), &hist), &mut rng, &mut out);
                assert_eq!(out, (0..12).collect::<Vec<_>>(), "{}", s.label());
                // full participation consumes no randomness
                assert_eq!(rng.next_u64(), before, "{}", s.label());
            }
        }
    }

    #[test]
    fn selection_is_deterministic_per_stream() {
        let hist = vec![0u32; 500];
        let widx = WeightIndex::build((0..500).map(|i| 1.0 + (i % 7) as f64));
        let selectors: Vec<Box<dyn Selector>> = vec![
            Box::new(Uniform),
            Box::new(WeightProportional),
            Box::new(StalenessAware(2)),
        ];
        for s in &selectors {
            let run = || {
                let mut rng = Rng::new(77);
                let mut out = Vec::new();
                s.select(&ctx(500, 32, 3, Some(&widx), &hist), &mut rng, &mut out);
                out
            };
            assert_eq!(run(), run(), "{}", s.label());
        }
    }

    #[test]
    fn weighted_sampling_prefers_heavy_clients() {
        // client 9 holds half the total weight: across many rounds it
        // must be selected far more often than any light client
        let weights: Vec<f64> = (0..10).map(|i| if i == 9 { 9.0 } else { 1.0 }).collect();
        let widx = WeightIndex::build(weights.into_iter());
        let hist = vec![0u32; 10];
        let mut heavy = 0usize;
        let mut light0 = 0usize;
        for round in 0..2000 {
            let mut rng = Rng::new(round as u64);
            let mut out = Vec::new();
            WeightProportional.select(&ctx(10, 2, round, Some(&widx), &hist), &mut rng, &mut out);
            heavy += out.contains(&9) as usize;
            light0 += out.contains(&0) as usize;
        }
        assert!(heavy > 2 * light0, "heavy {heavy} vs light {light0}");
    }

    #[test]
    fn weight_index_respects_proportions() {
        let widx = WeightIndex::build([1.0, 3.0].into_iter());
        assert_eq!(widx.len(), 2);
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 2];
        for _ in 0..20_000 {
            counts[widx.sample(&mut rng)] += 1;
        }
        let frac = counts[1] as f64 / 20_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn staleness_skips_recently_invited_clients() {
        // clients 0..50 invited last round: a tau=3 selection at the
        // next round must avoid them entirely (fresh pool is ample)
        let mut hist = vec![0u32; 200];
        for h in hist.iter_mut().take(50) {
            *h = 10; // invited at round 9
        }
        let mut rng = Rng::new(3);
        let mut out = Vec::new();
        StalenessAware(3).select(&ctx(200, 20, 10, None, &hist), &mut rng, &mut out);
        assert_eq!(out.len(), 20);
        assert!(out.iter().all(|&i| i >= 50), "picked a recent client: {out:?}");
        // once the window passes they are eligible again
        let mut rng = Rng::new(3);
        let mut out2 = Vec::new();
        StalenessAware(3).select(&ctx(200, 20, 13, None, &hist), &mut rng, &mut out2);
        // same stream, no rejections left -> the raw draws come through
        assert!(out2.iter().any(|&i| i < 50) || out2 == out);
    }

    #[test]
    fn staleness_falls_back_deterministically_when_everyone_is_recent() {
        // every client invited last round: the fresh pool is empty, so
        // the fallback must still fill the cohort, deterministically
        let hist = vec![5u32; 30]; // all invited at round 4
        let run = || {
            let mut rng = Rng::new(11);
            let mut out = Vec::new();
            StalenessAware(10).select(&ctx(30, 8, 5, None, &hist), &mut rng, &mut out);
            out
        };
        let a = run();
        assert_eq!(a.len(), 8);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(a, run());
    }
}
