//! The shared round-advance core (PR-8): the drift environment and the
//! due/memo/adopt/realize state machine that [`crate::sim::RoundSimulator`],
//! [`crate::sim::PopulationSimulator`], and the allocator service
//! ([`crate::service::AllocatorService`]) all execute.
//!
//! Before PR-8 the round loop lived twice — once in `sim::dynamic`,
//! once (transcribed) in `sim::population` — and the allocator service
//! would have made a third copy. This module extracts the loop body as
//! plain data + methods whose statements are transplanted **verbatim**
//! from the simulators, so the extraction moves no bits: the existing
//! `prop_dynamic` / `prop_population` suites pin the simulators'
//! outputs, and `prop_service` pins the service replay against the
//! simulators on every preset.
//!
//! * [`DriftEnv`] — one scenario whose gains / compute / membership
//!   evolve per round from the three seeded streams the round simulator
//!   forks (`jitter`, `dropout`, channel-process seed). This is the
//!   former `sim::population::DenseEnv`, promoted: the round simulator
//!   now runs on it too instead of inlining the same statements.
//! * [`RoundCore`] — the per-run mutable state: incumbent/initial/memo
//!   allocations, drift dirtiness, progress remaining, the run-length
//!   compressed delay/energy accumulators, and the per-round records.
//!   Everything in it is plain data (no caches beyond the bit-transparent
//!   [`ColumnCache`]), which is exactly what makes the service's
//!   checkpoint/resume bit-exact: serialize the core, rebuild the
//!   immutable context, continue.
//! * [`StepCtx`] — the per-run immutable context (convergence model,
//!   caches, objective, strategy, and an engine label for error
//!   messages).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::delay::{Allocation, ColumnCache, ConvergenceModel, Scenario, WorkloadCache};
use crate::model::WorkloadTable;
use crate::net::{ChannelModel, ChannelProcess, ChannelState};
use crate::opt::policy::{solve_with_repair, AllocationPolicy};
use crate::opt::Objective;
use crate::sim::dynamic::{round_cost, DynamicOutcome, ReOptStrategy, RoundCost, RoundRecord};
use crate::sim::faults::RoundOverlay;
use crate::util::rng::Rng;

/// Which candidate the adoption step kept this round — streamed by the
/// allocator service's `AllocationDecision` records; the simulators
/// ignore it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Adoption {
    /// No re-solve was due: the incumbent simply carried over.
    Held,
    /// A re-solve ran (or was served from the memo) and the incumbent
    /// still won the comparison.
    Incumbent,
    /// The round-0 allocation was re-adopted.
    Initial,
    /// The fresh (or memoized-fresh) solve won.
    Fresh,
}

impl Adoption {
    /// Stable lowercase label for records and JSONL.
    pub fn label(&self) -> &'static str {
        match self {
            Adoption::Held => "held",
            Adoption::Incumbent => "incumbent",
            Adoption::Initial => "initial",
            Adoption::Fresh => "fresh",
        }
    }
}

/// What [`RoundCore::maybe_reopt`] decided this round.
#[derive(Clone, Debug)]
pub struct ReOptOutcome {
    /// Whether the strategy (or a forced request) re-solved this round.
    pub resolved: bool,
    /// The adopted allocation's round cost, when one was computed on
    /// the final (post-adoption) allocation — reused by the realize
    /// step so no round evaluates one allocation twice.
    pub cost: Option<RoundCost>,
    /// Which candidate won (== `Held` iff `resolved` is false).
    pub adopted: Adoption,
    /// Feasibility-repair tier of this round's solve (PR-10): 0 on the
    /// healthy path (including `Held` rounds); see
    /// [`crate::opt::solve_with_repair`].
    pub repair_tier: u8,
    /// Clients shed by a tier-3 repair this round (view-indices; their
    /// allocation rows are empty). The run loop must drop them from the
    /// round's participation mask before realizing.
    pub shed: Vec<usize>,
}

/// One scenario whose gains / compute capabilities / membership evolve
/// per round: the exact shared-stream evolution `RoundSimulator` has
/// always performed, as a reusable value. The population engine's dense
/// mode and the allocator service run the same statements, which is
/// what makes the degenerate-population and service-replay anchor
/// invariants bit-exact rather than approximate.
pub struct DriftEnv {
    /// Working scenario: gains and compute mutate in place.
    pub(crate) scn: Scenario,
    /// Static compute capabilities (jitter rescales from these).
    pub(crate) base_f: Vec<f64>,
    pub(crate) jitter_rng: Rng,
    pub(crate) drop_rng: Rng,
    pub(crate) process: ChannelProcess,
    pub(crate) active: Vec<bool>,
    pub(crate) jitter: f64,
    pub(crate) dropout: f64,
    pub(crate) rejoin: f64,
}

impl DriftEnv {
    /// Build the drift state over `scn` (a working copy the caller
    /// hands over) from its own resolved `dynamics`: the round
    /// simulator's stream forks, verbatim — independent seeded streams
    /// per dynamics knob, so toggling one never shifts another's draws.
    pub(crate) fn new(scn: Scenario) -> DriftEnv {
        let d = &scn.dynamics;
        let base_f: Vec<f64> = scn.topo.clients.iter().map(|c| c.f_cycles).collect();
        let mut root = Rng::new(d.seed);
        let jitter_rng = root.fork(0x4A17);
        let drop_rng = root.fork(0xD509);
        let process_seed = root.fork(0x5AD0).next_u64();
        let sigma = d.shadow_sigma_db.max(0.0);
        let model = ChannelModel::new(sigma);
        let state = ChannelState::recover(
            &scn.topo,
            &model,
            &scn.main_link.client_gain,
            &scn.fed_link.client_gain,
        );
        let process = ChannelProcess::new(model, state, d.rho, process_seed);
        let active = vec![true; scn.k()];
        let (jitter, dropout, rejoin) = (d.compute_jitter, d.dropout, d.rejoin);
        DriftEnv {
            scn,
            base_f,
            jitter_rng,
            drop_rng,
            process,
            active,
            jitter,
            dropout,
            rejoin,
        }
    }

    /// One round of environment evolution; returns whether anything the
    /// solver sees changed (gains or compute — membership is invisible
    /// to solves, as it always was in the round simulator).
    pub(crate) fn advance(&mut self) -> bool {
        let mut dirty = false;
        self.process.step();
        if !self.process.is_frozen() {
            let (main, fed) = self.process.gains(&self.scn.topo);
            self.scn.main_link.client_gain = main;
            self.scn.fed_link.client_gain = fed;
            dirty = true;
        }
        if self.jitter > 0.0 {
            for (c, &f0) in self.scn.topo.clients.iter_mut().zip(&self.base_f) {
                c.f_cycles = f0 * (self.jitter * self.jitter_rng.normal()).exp();
            }
            dirty = true;
        }
        if self.dropout > 0.0 {
            let prev = self.active.clone();
            for (k, a) in self.active.iter_mut().enumerate() {
                let u = self.drop_rng.f64();
                if prev[k] {
                    if u < self.dropout {
                        *a = false;
                    }
                } else if u < self.rejoin {
                    *a = true;
                }
            }
            if !self.active.iter().any(|&a| a) {
                // never simulate an empty federation
                self.active = prev;
            }
        }
        dirty
    }

    /// Apply a fault overlay for one round (PR-10), returning the undo
    /// state that restores the environment after the round is realized.
    /// Only called for non-empty overlays — the fault-free path never
    /// touches the environment, so zero-fault runs move no bits. The
    /// persistent drift state (channel process, base compute, streams)
    /// is untouched: faults perturb the *realized* scenario, not the
    /// processes behind it, which is what keeps the schedule overlay
    /// stateless.
    pub(crate) fn apply_overlay(&mut self, ov: &RoundOverlay) -> FaultUndo {
        let undo = FaultUndo {
            gains_main: self.scn.main_link.client_gain.clone(),
            gains_fed: self.scn.fed_link.client_gain.clone(),
            f_cycles: self.scn.topo.clients.iter().map(|c| c.f_cycles).collect(),
            active: self.active.clone(),
        };
        crate::sim::faults::apply_to_scenario(&mut self.scn, ov);
        for &k in &ov.crashed {
            if let Some(a) = self.active.get_mut(k) {
                *a = false;
            }
        }
        if !self.active.iter().any(|&a| a) {
            // never simulate an empty federation (the dropout process's
            // own guard, applied to crashes too)
            self.active = undo.active.clone();
        }
        undo
    }

    /// Restore the environment after a faulted round.
    pub(crate) fn undo_overlay(&mut self, undo: FaultUndo) {
        self.scn.main_link.client_gain = undo.gains_main;
        self.scn.fed_link.client_gain = undo.gains_fed;
        for (c, f) in self.scn.topo.clients.iter_mut().zip(undo.f_cycles) {
            c.f_cycles = f;
        }
        self.active = undo.active;
    }

    /// Force one client's membership (the service's `ClientDropped` /
    /// `ClientRejoined` events). Out of range is a descriptive error —
    /// event files are external input.
    pub(crate) fn set_member(&mut self, id: usize, online: bool) -> Result<()> {
        match self.active.get_mut(id) {
            Some(a) => {
                *a = online;
                Ok(())
            }
            None => bail!(
                "client id {id} out of range (scenario has {} clients)",
                self.scn.k()
            ),
        }
    }
}

/// Saved environment state bracketing one faulted round: everything a
/// [`RoundOverlay`] can touch, restored verbatim by
/// [`DriftEnv::undo_overlay`] after the round realizes.
pub(crate) struct FaultUndo {
    gains_main: Vec<f64>,
    gains_fed: Vec<f64>,
    f_cycles: Vec<f64>,
    active: Vec<bool>,
}

/// Per-run immutable context shared by every [`RoundCore`] step.
pub struct StepCtx<'a> {
    pub(crate) conv: &'a ConvergenceModel,
    pub(crate) cache: &'a WorkloadCache,
    pub(crate) table: &'a Arc<WorkloadTable>,
    pub(crate) objective: &'a Objective,
    pub(crate) strategy: ReOptStrategy,
    /// Candidate rank set — consumed only by the tier-2 baseline-d
    /// feasibility repair ([`crate::opt::solve_with_repair`]).
    pub(crate) ranks: &'a [usize],
    /// `"dynamic"` or `"population"` (or `"service"`): the engine name
    /// error contexts and the max-rounds bail print.
    pub(crate) label: &'a str,
}

/// The per-run mutable state of the round loop: what both simulators
/// used to keep in local variables, as one checkpointable value. Field
/// semantics are documented where the simulators documented them; the
/// statements in the methods are transplanted verbatim.
pub struct RoundCore {
    /// The round-0 allocation (a re-adoption candidate until retired).
    pub(crate) alloc0: Allocation,
    /// The incumbent allocation.
    pub(crate) alloc: Allocation,
    /// Whether the incumbent currently *is* the round-0 allocation
    /// (lets the adoption step skip evaluating alloc0 twice).
    pub(crate) incumbent_is_initial: bool,
    /// Once true, `alloc0` is never a candidate again (the population
    /// engine retires it when the cohort first changes: its vectors
    /// index clients no longer in the view). Always false in the round
    /// simulator.
    pub(crate) initial_retired: bool,
    /// The last actually-solved allocation, valid as the "fresh"
    /// candidate while the environment has not drifted since.
    pub(crate) memo_fresh_alloc: Allocation,
    pub(crate) env_dirty: bool,
    /// One-shot override: the next `maybe_reopt` is due regardless of
    /// strategy (the service's `ReOptRequested` event). Never set by
    /// the simulators.
    pub(crate) force_reopt: bool,
    pub(crate) fresh_solves: usize,
    pub(crate) resolves: usize,
    pub(crate) deadline_drops: usize,
    /// Total faults injected so far (PR-10; 0 on fault-free runs).
    pub(crate) faults_injected: usize,
    /// Highest feasibility-repair tier any round needed (PR-10; 0 on
    /// healthy runs).
    pub(crate) repair_max: u8,
    /// Rounds left to convergence at the current rank.
    pub(crate) remaining: f64,
    /// Round delay at the last solve (OnDegrade reference).
    pub(crate) solved_delay: f64,
    /// Eq. 17's static prediction for the round-0 solve.
    pub(crate) static_prediction: f64,
    pub(crate) round: usize,
    /// Per-candidate rate/power columns, refreshed only where gains
    /// actually moved (3 live candidates + 1 slack). Bit-transparent:
    /// never serialized, rebuilt cold on resume.
    pub(crate) col_cache: ColumnCache,
    // realized-delay accumulator: run-length compressed so equal
    // consecutive round delays collapse into one weight×delay product
    // (see sim::dynamic module docs); energy gets its own segments so
    // its frozen closed form is equally bit-exact
    pub(crate) realized: f64,
    pub(crate) seg_weight: f64,
    pub(crate) seg_delay: f64,
    pub(crate) realized_e: f64,
    pub(crate) seg_weight_e: f64,
    pub(crate) seg_energy: f64,
    /// Per-round trace, in order. A resumed core restarts this empty —
    /// already-streamed records live in the metric sink, not the
    /// checkpoint — so totals must come from the scalar accumulators.
    pub(crate) rounds: Vec<RoundRecord>,
}

impl RoundCore {
    /// Fresh core after the round-0 solve: `alloc0` is the incumbent,
    /// the memo, and the re-adoption candidate.
    pub(crate) fn new(
        alloc0: Allocation,
        static_prediction: f64,
        conv: &ConvergenceModel,
    ) -> RoundCore {
        let remaining = conv.rounds(alloc0.rank);
        RoundCore {
            alloc: alloc0.clone(),
            memo_fresh_alloc: alloc0.clone(),
            alloc0,
            incumbent_is_initial: true,
            initial_retired: false,
            env_dirty: false,
            force_reopt: false,
            fresh_solves: 0,
            resolves: 0,
            deadline_drops: 0,
            faults_injected: 0,
            repair_max: 0,
            remaining,
            solved_delay: f64::INFINITY,
            static_prediction,
            round: 0,
            col_cache: ColumnCache::new(4),
            realized: 0.0,
            seg_weight: 0.0,
            seg_delay: 0.0,
            realized_e: 0.0,
            seg_weight_e: 0.0,
            seg_energy: 0.0,
            rounds: Vec::new(),
        }
    }

    /// True once one unit of convergence progress has been realized.
    pub(crate) fn done(&self) -> bool {
        !(self.remaining > 0.0)
    }

    /// The simulators' max-rounds guard, verbatim (the label keeps each
    /// engine's historical message).
    pub(crate) fn check_cap(&self, max_rounds: usize, ctx: &StepCtx) -> Result<()> {
        if self.round >= max_rounds {
            bail!(
                "{} run exceeded dynamics.max_rounds = {} \
                 (strategy {}, {:.1} rounds still remaining)",
                ctx.label,
                max_rounds,
                ctx.strategy.label(),
                self.remaining
            );
        }
        Ok(())
    }

    /// Realized per-round cost of `alloc` on `scn` under `active`,
    /// through this core's delta [`ColumnCache`].
    pub(crate) fn cost_of(
        &mut self,
        ctx: &StepCtx,
        scn: &Scenario,
        alloc: &Allocation,
        active: &[bool],
    ) -> RoundCost {
        round_cost(scn, ctx.conv, ctx.table, alloc, active, ctx.objective, &mut self.col_cache)
    }

    /// Replace the incumbent after a cohort change (the population
    /// engine's re-communication): the round-0 allocation indexes
    /// clients that are no longer in the view — retire it as a
    /// re-adoption candidate for good.
    pub(crate) fn rebase_incumbent(&mut self, alloc: Allocation) {
        self.alloc = alloc;
        self.initial_retired = true;
        self.incumbent_is_initial = false;
    }

    /// The strategy decision + memoized fresh solve + candidate
    /// adoption, transplanted verbatim from the simulators. Only
    /// meaningful for `round > 0` (round 0 solves before the loop).
    pub(crate) fn maybe_reopt(
        &mut self,
        ctx: &StepCtx,
        policy: &dyn AllocationPolicy,
        scn: &Scenario,
        active: &[bool],
    ) -> Result<ReOptOutcome> {
        // --- decide whether to re-solve. The incumbent's cost computed
        // for the OnDegrade trigger seeds the adoption step below, so
        // no round evaluates one allocation twice.
        let mut cost_round: Option<RoundCost> = None;
        let mut incumbent_cost: Option<RoundCost> = None;
        let strategy_due = match ctx.strategy {
            ReOptStrategy::OneShot => false,
            ReOptStrategy::EveryRound => true,
            ReOptStrategy::Periodic(j) => self.round % j.max(1) == 0,
            ReOptStrategy::OnDegrade(th) => {
                let cost = self.cost_of(ctx, scn, &self.alloc.clone(), active);
                let triggered = cost.delay > self.solved_delay * (1.0 + th);
                cost_round = Some(cost);
                incumbent_cost = Some(cost);
                triggered
            }
        };
        // a forced request (service ReOptRequested) is checked after
        // the strategy match, so strategy draws/evaluations are
        // untouched when no force is pending — the simulators never
        // force, so their bits cannot move
        let due = strategy_due || self.force_reopt;
        self.force_reopt = false;
        if !due {
            return Ok(ReOptOutcome {
                resolved: false,
                cost: cost_round,
                adopted: Adoption::Held,
                repair_tier: 0,
                shed: Vec::new(),
            });
        }
        // Warm start: while nothing in the environment has drifted
        // since the last actual solve, the policy — a deterministic
        // function of the scenario — would reproduce the memoized
        // allocation bit for bit, so it IS the fresh candidate (zero
        // solver work; the frozen-run invariant prop_dynamic asserts).
        let fresh_alloc = if self.env_dirty {
            let fresh =
                solve_with_repair(policy, scn, ctx.conv, ctx.cache, Some(&self.alloc), ctx.ranks)
                    .with_context(|| {
                        format!("{} run: re-solve at round {}", ctx.label, self.round)
                    })?;
            self.fresh_solves += 1;
            if fresh.repair_tier > 0 {
                // Degraded solve (PR-10): adopt the repaired allocation
                // directly. The 3-way compare is skipped — a shed
                // allocation scores infinite against the still-full
                // active mask, and the repair tiers already picked the
                // best finite fallback. The environment stays dirty and
                // nothing is memoized: the next due round must try a
                // clean solve again rather than replay the repair.
                self.resolves += 1;
                self.repair_max = self.repair_max.max(fresh.repair_tier);
                if fresh.alloc.rank != self.alloc.rank {
                    let e_old = ctx.conv.rounds(self.alloc.rank);
                    let e_new = ctx.conv.rounds(fresh.alloc.rank);
                    self.remaining *= e_new / e_old;
                }
                self.alloc = fresh.alloc;
                self.incumbent_is_initial = false;
                return Ok(ReOptOutcome {
                    resolved: true,
                    cost: None,
                    adopted: Adoption::Fresh,
                    repair_tier: fresh.repair_tier,
                    shed: fresh.shed,
                });
            }
            self.env_dirty = false;
            self.memo_fresh_alloc = fresh.alloc.clone();
            fresh.alloc
        } else {
            self.memo_fresh_alloc.clone()
        };
        self.resolves += 1;
        // adopt the cheapest of {incumbent, round-0, fresh} under the
        // *current* channel (objective score per unit of progress);
        // ties keep the earlier candidate, so a frozen channel never
        // churns the allocation. The round-0 candidate is skipped while
        // the incumbent *is* the round-0 allocation, and forever once
        // it has been retired by a cohort change.
        let mut best = match incumbent_cost {
            Some(cost) => cost,
            None => self.cost_of(ctx, scn, &self.alloc.clone(), active),
        };
        let mut best_alloc = self.alloc.clone();
        let mut adopted = Adoption::Incumbent;
        if !self.incumbent_is_initial && !self.initial_retired {
            let c0 = self.cost_of(ctx, scn, &self.alloc0.clone(), active);
            if c0.score < best.score {
                best = c0;
                best_alloc = self.alloc0.clone();
                self.incumbent_is_initial = true;
                adopted = Adoption::Initial;
            }
        }
        let cf = self.cost_of(ctx, scn, &fresh_alloc, active);
        if cf.score < best.score {
            best = cf;
            best_alloc = fresh_alloc;
            self.incumbent_is_initial = false;
            adopted = Adoption::Fresh;
        }
        if best_alloc.rank != self.alloc.rank {
            // convert the remaining progress to the new rank's round
            // count
            let e_old = ctx.conv.rounds(self.alloc.rank);
            let e_new = ctx.conv.rounds(best_alloc.rank);
            self.remaining *= e_new / e_old;
        }
        self.alloc = best_alloc;
        Ok(ReOptOutcome {
            resolved: true,
            cost: Some(best),
            adopted,
            repair_tier: 0,
            shed: Vec::new(),
        })
    }

    /// Realize the current round: compute (or reuse) the round cost,
    /// fold it into the run-length segments, record it, and advance
    /// progress. Transplanted verbatim from the simulators.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn realize(
        &mut self,
        ctx: &StepCtx,
        scn: &Scenario,
        active: &[bool],
        cost_round: Option<RoundCost>,
        resolved: bool,
        cohort: usize,
        dropped: usize,
        faults: usize,
        repair_tier: u8,
    ) -> RoundRecord {
        let cost = match cost_round {
            Some(c) => c,
            None => self.cost_of(ctx, scn, &self.alloc.clone(), active),
        };
        let (d, e) = (cost.delay, cost.energy);
        if resolved {
            self.solved_delay = d;
        }
        let weight = if self.remaining < 1.0 { self.remaining } else { 1.0 };
        if self.seg_weight > 0.0 && d.to_bits() == self.seg_delay.to_bits() {
            self.seg_weight += weight;
        } else {
            self.realized += self.seg_weight * self.seg_delay;
            self.seg_weight = weight;
            self.seg_delay = d;
        }
        if self.seg_weight_e > 0.0 && e.to_bits() == self.seg_energy.to_bits() {
            self.seg_weight_e += weight;
        } else {
            self.realized_e += self.seg_weight_e * self.seg_energy;
            self.seg_weight_e = weight;
            self.seg_energy = e;
        }
        let record = RoundRecord {
            round: self.round,
            weight,
            delay: d,
            energy: e,
            l_c: self.alloc.l_c,
            rank: self.alloc.rank,
            active: active.iter().filter(|&&a| a).count(),
            resolved,
            cohort,
            dropped,
            faults,
            repair_tier,
        };
        self.rounds.push(record.clone());
        self.remaining -= weight;
        self.round += 1;
        record
    }

    /// Realized totals so far, with the open run-length segments
    /// flushed (without consuming the core — the service reads totals
    /// mid-run for summaries and checkpoints).
    pub(crate) fn totals(&self) -> (f64, f64) {
        (
            self.realized + self.seg_weight * self.seg_delay,
            self.realized_e + self.seg_weight_e * self.seg_energy,
        )
    }

    /// Close the run into the simulators' outcome type.
    pub(crate) fn finish(self, unique_participants: usize) -> DynamicOutcome {
        let (realized_delay, realized_energy) = self.totals();
        DynamicOutcome {
            realized_delay,
            realized_energy,
            static_prediction: self.static_prediction,
            final_alloc: self.alloc,
            rounds: self.rounds,
            resolves: self.resolves,
            fresh_solves: self.fresh_solves,
            unique_participants,
            deadline_drops: self.deadline_drops,
            faults_injected: self.faults_injected,
            repair_max: self.repair_max,
        }
    }
}
