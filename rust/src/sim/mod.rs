//! Experiment harness: scenario construction, policy sweeps, reports.
//!
//! Three pieces (see DESIGN.md for the architecture):
//!
//! * [`builder`] — [`ScenarioBuilder`]: fluent, seeded scenario
//!   construction with named heterogeneity presets (`paper`,
//!   `dense_cell`, `weak_edge`, `asymmetric_links`);
//! * [`mod@sweep`] — [`SweepAxis`] / [`SweepRunner`] / [`SweepReport`]:
//!   declarative *policies × grid* sweeps fanned out across
//!   `std::thread` workers, with deterministic CSV/JSON reports;
//! * the policies themselves live in [`crate::opt::policy`].
//!
//! Every figure bench (Figs. 5–8), the `optimize`/`latency`/`sweep`
//! CLI subcommands, and the resource-allocation example run on this
//! API. The old `build_scenario`/`sweep` free functions remain as thin
//! deprecated shims.

pub mod builder;
pub mod sweep;

pub use self::builder::{ScenarioBuilder, PRESETS};
pub use self::sweep::{PointResult, SweepAxis, SweepReport, SweepRunner};

use anyhow::Result;

use crate::config::Config;
use crate::delay::Scenario;

/// Build a scenario straight from a config.
#[deprecated(note = "use sim::ScenarioBuilder::from_config(cfg).build()")]
pub fn build_scenario(cfg: &Config) -> Result<Scenario> {
    ScenarioBuilder::from_config(cfg.clone()).build()
}

/// Materialize `(value, scenario)` pairs for a one-axis sweep.
#[deprecated(note = "use sim::SweepRunner with a SweepAxis")]
pub fn sweep<F: Fn(&mut Config, f64)>(
    base: &Config,
    values: &[f64],
    apply: F,
) -> Result<Vec<(f64, Scenario)>> {
    let mut out = Vec::with_capacity(values.len());
    for &v in values {
        let mut cfg = base.clone();
        apply(&mut cfg, v);
        out.push((v, ScenarioBuilder::from_config(cfg).build()?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the shims themselves are under test here
    use super::*;

    #[test]
    fn build_scenario_shim_matches_builder() {
        let cfg = Config::paper_defaults();
        let a = build_scenario(&cfg).unwrap();
        let b = ScenarioBuilder::from_config(cfg).build().unwrap();
        assert_eq!(a.main_link.client_gain, b.main_link.client_gain);
        assert_eq!(a.k(), b.k());
    }

    #[test]
    fn sweep_shim_applies_parameter() {
        let cfg = Config::paper_defaults();
        let pts = sweep(&cfg, &[250e3, 500e3, 1000e3], |c, v| {
            c.system.bandwidth_main_hz = v;
        })
        .unwrap();
        assert_eq!(pts.len(), 3);
        assert!((pts[0].1.main_link.subch.total_hz() - 250e3).abs() < 1e-6);
        assert!((pts[2].1.main_link.subch.total_hz() - 1000e3).abs() < 1e-6);
    }
}
