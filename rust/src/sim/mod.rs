//! Experiment harness: scenario construction, policy sweeps, reports.
//!
//! Three pieces (see DESIGN.md for the architecture):
//!
//! * [`builder`] — [`ScenarioBuilder`]: fluent, seeded scenario
//!   construction with named heterogeneity presets (`paper`,
//!   `dense_cell`, `weak_edge`, `asymmetric_links`, `many_clients`);
//! * [`mod@sweep`] — [`SweepAxis`] / [`SweepRunner`] / [`SweepReport`]:
//!   declarative *policies × grid* sweeps fanned out across
//!   `std::thread` workers, with deterministic CSV/JSON reports,
//!   per-point error rows for infeasible grid corners, and a shared
//!   [`crate::delay::WorkloadCache`] across grid points;
//! * the policies themselves live in [`crate::opt::policy`].
//!
//! Every figure bench (Figs. 5–8), the `optimize`/`latency`/`sweep`
//! CLI subcommands, and the resource-allocation example run on this
//! API. (The deprecated `build_scenario`/`sweep` free functions are
//! gone — `ScenarioBuilder::from_config(cfg).build()` and
//! [`SweepRunner`] are the only spellings.)

pub mod builder;
pub mod sweep;

pub use self::builder::{ScenarioBuilder, PRESETS};
pub use self::sweep::{PointError, PointResult, SweepAxis, SweepReport, SweepRunner};
