//! Experiment harness: build [`Scenario`]s from a [`Config`], run
//! parameter sweeps, and evaluate allocations — the machinery behind
//! every figure bench (Figs. 5–8) and the resource-allocation example.

use anyhow::Result;

use crate::config::Config;
use crate::delay::Scenario;
use crate::model::{Gpt2Config, WorkloadProfile};
use crate::net::{power, ChannelModel, Link, SubchannelSet, Topology};
use crate::util::rng::Rng;

/// Build a scenario from a config: sample geometry/capabilities with the
/// config seed, draw shadowed channel gains, construct both links.
pub fn build_scenario(cfg: &Config) -> Result<Scenario> {
    let s = &cfg.system;
    let mut rng = Rng::new(s.seed);
    let topo = Topology::sample(
        s.clients,
        s.d_max_m,
        s.d_main_m,
        s.f_client_lo,
        s.f_client_hi,
        &mut rng,
    );
    let ch = ChannelModel::new(s.shadowing_db);
    let mut gain_rng = rng.fork(0xC0FFEE);
    let main_gain: Vec<f64> = topo
        .clients
        .iter()
        .map(|c| ch.gain(c.d_main_m, &mut gain_rng))
        .collect();
    let fed_gain: Vec<f64> = topo
        .clients
        .iter()
        .map(|c| ch.gain(c.d_fed_m, &mut gain_rng))
        .collect();
    let noise = power::dbm_per_hz_to_watt_per_hz(s.noise_dbm_hz);

    let arch = Gpt2Config::by_name(&cfg.model)?;
    let profile = WorkloadProfile::new(arch, cfg.train.seq);

    Ok(Scenario {
        profile,
        topo,
        main_link: Link {
            subch: SubchannelSet::equal_split(s.bandwidth_main_hz, s.subch_main),
            gain_product: s.gain_main,
            noise_psd: noise,
            client_gain: main_gain,
        },
        fed_link: Link {
            subch: SubchannelSet::equal_split(s.bandwidth_fed_hz, s.subch_fed),
            gain_product: s.gain_fed,
            noise_psd: noise,
            client_gain: fed_gain,
        },
        kappa_client: s.kappa_client,
        kappa_server: s.kappa_server,
        f_server: s.f_server,
        batch: cfg.train.batch,
        local_steps: cfg.train.local_steps,
        p_max_w: power::dbm_to_watt(s.p_max_dbm),
        p_th_main_w: power::dbm_to_watt(s.p_th_main_dbm),
        p_th_fed_w: power::dbm_to_watt(s.p_th_fed_dbm),
    })
}

/// A single sweep point: modify a copy of the base config, rebuild the
/// scenario. Used by the figure benches.
pub fn sweep<F: Fn(&mut Config, f64)>(
    base: &Config,
    values: &[f64],
    apply: F,
) -> Result<Vec<(f64, Scenario)>> {
    let mut out = Vec::with_capacity(values.len());
    for &v in values {
        let mut cfg = base.clone();
        apply(&mut cfg, v);
        out.push((v, build_scenario(&cfg)?));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_paper_scenario() {
        let cfg = Config::paper_defaults();
        let scn = build_scenario(&cfg).unwrap();
        assert_eq!(scn.k(), 5);
        assert_eq!(scn.main_link.subch.len(), 20);
        assert_eq!(scn.profile.blocks.len(), 12); // gpt2-s
        assert!((scn.p_max_w - 15.0).abs() < 0.05);
        // every gain positive and sane
        for &g in scn.main_link.client_gain.iter().chain(&scn.fed_link.client_gain) {
            assert!(g > 0.0 && g < 1.0);
        }
    }

    #[test]
    fn same_seed_same_scenario() {
        let cfg = Config::paper_defaults();
        let a = build_scenario(&cfg).unwrap();
        let b = build_scenario(&cfg).unwrap();
        assert_eq!(a.main_link.client_gain, b.main_link.client_gain);
        assert_eq!(
            a.topo.clients.iter().map(|c| c.f_cycles).collect::<Vec<_>>(),
            b.topo.clients.iter().map(|c| c.f_cycles).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sweep_applies_parameter() {
        let cfg = Config::paper_defaults();
        let pts = sweep(&cfg, &[250e3, 500e3, 1000e3], |c, v| {
            c.system.bandwidth_main_hz = v;
        })
        .unwrap();
        assert_eq!(pts.len(), 3);
        assert!((pts[0].1.main_link.subch.total_hz() - 250e3).abs() < 1e-6);
        assert!((pts[2].1.main_link.subch.total_hz() - 1000e3).abs() < 1e-6);
    }
}
