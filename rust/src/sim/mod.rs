//! Experiment harness: scenario construction, policy sweeps, dynamic
//! multi-round simulation, reports.
//!
//! Four pieces (see DESIGN.md for the architecture):
//!
//! * [`builder`] — [`ScenarioBuilder`]: fluent, seeded scenario
//!   construction with named heterogeneity presets (`paper`,
//!   `dense_cell`, `weak_edge`, `asymmetric_links`, `many_clients`,
//!   `mobile_edge`, `battery_edge`, `metro_population`), including the round-varying
//!   dynamics knobs and the objective/energy parameters;
//! * [`mod@sweep`] — [`SweepAxis`] / [`SweepRunner`] / [`SweepReport`]:
//!   declarative *policies × grid* sweeps fanned out across
//!   `std::thread` workers, with deterministic CSV/JSON reports,
//!   per-point error rows for infeasible grid corners, and a shared
//!   [`crate::delay::WorkloadCache`] across grid points;
//! * [`dynamic`] — [`RoundSimulator`] / [`ReOptStrategy`] /
//!   [`DynamicPolicy`]: the round-varying engine — AR(1) channel
//!   drift, compute jitter, dropout — that accumulates *realized*
//!   total delay **and realized energy** and re-optimizes mid-run
//!   (`one_shot`, `every_round`, `periodic:J`, `on_degrade:θ`);
//! * [`engine`] — the shared round-advance core ([`engine::DriftEnv`] /
//!   [`engine::RoundCore`]): the drift evolution and the
//!   due/memo/adopt/realize state machine that both simulators and the
//!   allocator service ([`crate::service`]) execute, extracted in PR-8
//!   so checkpoint/resume serializes one canonical state;
//! * [`population`] + [`selector`] — [`Population`] /
//!   [`PopulationSimulator`]: the event-driven population engine —
//!   10^5–10^6 modeled clients with lazily materialized per-client
//!   state, per-round cohort [`Selector`]s (`uniform`, `weighted`,
//!   `staleness:τ`), straggler deadlines, and dropout/rejoin, at
//!   O(cohort) per-round cost (the `metro_population` preset and the
//!   `population` CLI subcommand run on it);
//! * [`faults`] — [`FaultPlan`] / [`FaultInjector`]: seeded,
//!   deterministic fault injection (client crashes, compute stalls,
//!   subchannel outages, federated-server blackouts) with a stateless
//!   per-round overlay; the empty plan is bit-transparent, and the
//!   `chaos` CLI subcommand runs the preset × fault-matrix table;
//! * the policies themselves live in [`crate::opt::policy`].
//!
//! Every figure bench (Figs. 5–8), the
//! `optimize`/`latency`/`sweep`/`dynamic` CLI subcommands, and the
//! resource-allocation / dynamic-reopt examples run on this API. (The
//! deprecated `build_scenario`/`sweep` free functions are gone —
//! `ScenarioBuilder::from_config(cfg).build()` and [`SweepRunner`] are
//! the only spellings.)

pub mod builder;
pub mod dynamic;
pub mod engine;
pub mod faults;
pub mod population;
pub mod selector;
pub mod sweep;

pub use self::builder::{ScenarioBuilder, PRESETS};
pub use self::faults::{FaultInjector, FaultPlan, RoundOverlay};
pub use self::dynamic::{
    DynamicOutcome, DynamicPolicy, ReOptStrategy, RoundRecord, RoundSimulator,
};
pub use self::population::{Observation, Population, PopulationSimulator, PopulationState};
pub use self::selector::{
    parse_selector, SelectionCtx, Selector, StalenessAware, Uniform, WeightIndex,
    WeightProportional,
};
pub use self::sweep::{PointError, PointResult, SweepAxis, SweepReport, SweepRunner};
