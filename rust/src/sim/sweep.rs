//! Declarative parameter sweeps: *policies × scenario grid*, fanned out
//! across `std::thread` workers.
//!
//! A [`SweepAxis`] names one config dimension and the values to visit
//! (canned constructors cover the Figs. 5–8 axes); [`SweepRunner`]
//! takes a base [`ScenarioBuilder`], one or more axes (their cartesian
//! product forms the grid), and a policy list from the
//! [`crate::opt::PolicyRegistry`], and produces a [`SweepReport`] with
//! CSV/JSON writers.
//!
//! Every grid point is an independent pure computation (scenario
//! sampling and all policies are seeded), so points are distributed
//! over a work-stealing index and written back by position: reports are
//! **byte-identical at any thread count** — asserted by the
//! determinism test in `rust/tests/prop_policy.rs`.
//!
//! All points share one [`crate::delay::WorkloadCache`], so every grid
//! point with the same model/sequence/rank set reuses the cached
//! per-(l_c, rank) workload tables, and an infeasible grid point (say,
//! a `clients` value exceeding the subchannel count) is recorded as a
//! [`PointError`] row instead of failing the whole sweep.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Config;
use crate::delay::{ConvergenceModel, WorkloadCache};
use crate::opt::policy::{AllocationPolicy, PolicyOutcome};
use crate::sim::builder::ScenarioBuilder;
use crate::util::csv::{ensure_parent_dir, escape_field};

/// One sweep dimension: a report column name, the values to visit (in
/// the column's display unit), and how a value maps onto the config.
#[derive(Clone)]
pub struct SweepAxis {
    pub name: String,
    pub values: Vec<f64>,
    apply: Arc<dyn Fn(&mut Config, f64) + Send + Sync>,
}

impl SweepAxis {
    /// A custom axis. `apply` receives the value exactly as listed in
    /// `values`, so unit conversion belongs inside the closure.
    pub fn new<F>(name: &str, values: &[f64], apply: F) -> SweepAxis
    where
        F: Fn(&mut Config, f64) + Send + Sync + 'static,
    {
        SweepAxis {
            name: name.to_string(),
            values: values.to_vec(),
            apply: Arc::new(apply),
        }
    }

    /// Fig. 5 axis: per-link bandwidth in kHz, applied to both links.
    pub fn bandwidth_khz(values: &[f64]) -> SweepAxis {
        SweepAxis::new("bandwidth_khz", values, |cfg, v| {
            cfg.system.bandwidth_main_hz = v * 1e3;
            cfg.system.bandwidth_fed_hz = v * 1e3;
        })
    }

    /// Fig. 6 axis: client computing capability in FLOPs per cycle
    /// (κ_client = 1/v).
    pub fn client_flops_per_cycle(values: &[f64]) -> SweepAxis {
        SweepAxis::new("client_flops_per_cycle", values, |cfg, v| {
            cfg.system.kappa_client = 1.0 / v;
        })
    }

    /// Fig. 7 axis: main-server capability in GHz (cycles/s × 1e9).
    pub fn server_compute_ghz(values: &[f64]) -> SweepAxis {
        SweepAxis::new("f_server_ghz", values, |cfg, v| {
            cfg.system.f_server = v * 1e9;
        })
    }

    /// Fig. 8 axis: per-client maximum transmit power in dBm.
    pub fn p_max_dbm(values: &[f64]) -> SweepAxis {
        SweepAxis::new("p_max_dbm", values, |cfg, v| {
            cfg.system.p_max_dbm = v;
        })
    }

    /// Scaling axis: number of participating clients K (values are
    /// rounded; K >= 1 is enforced, and the scenario build rejects
    /// grids where K exceeds the subchannel counts).
    pub fn clients(values: &[f64]) -> SweepAxis {
        SweepAxis::new("clients", values, |cfg, v| {
            cfg.system.clients = v.round().max(1.0) as usize;
        })
    }

    /// Dynamics axis: AR(1) round-to-round shadowing correlation ρ
    /// (1.0 = static channel). Meaningful for
    /// [`crate::sim::DynamicPolicy`] columns.
    pub fn channel_correlation(values: &[f64]) -> SweepAxis {
        SweepAxis::new("channel_rho", values, |cfg, v| {
            cfg.dynamics.rho = v;
        })
    }

    /// Dynamics axis: per-round client dropout probability.
    pub fn dropout(values: &[f64]) -> SweepAxis {
        SweepAxis::new("dropout", values, |cfg, v| {
            cfg.dynamics.dropout = v;
        })
    }

    /// Dynamics axis: re-optimization period J — sets the config
    /// strategy to `periodic:<J>` (values are rounded, J >= 1), which
    /// [`crate::sim::DynamicPolicy::from_scenario`] columns pick up.
    pub fn reopt_period(values: &[f64]) -> SweepAxis {
        SweepAxis::new("reopt_period", values, |cfg, v| {
            cfg.dynamics.strategy = format!("periodic:{}", (v.round().max(1.0)) as usize);
        })
    }

    /// Energy axis: switched-capacitance ζ (J·s²/cycle³) of the client
    /// compute-energy model — the device-efficiency dimension of the
    /// energy/delay trade-off.
    pub fn zeta(values: &[f64]) -> SweepAxis {
        SweepAxis::new("zeta", values, |cfg, v| {
            cfg.objective.zeta = v;
        })
    }

    /// Energy axis: λ of the weighted objective `T + λ·E` (s/J). Also
    /// forces `objective.kind = "weighted"` so the axis is effective on
    /// any base config — λ = 0 is exactly the delay objective.
    pub fn lambda(values: &[f64]) -> SweepAxis {
        SweepAxis::new("lambda", values, |cfg, v| {
            cfg.objective.kind = "weighted".to_string();
            cfg.objective.lambda = v;
        })
    }

    /// Canned axis lookup for the CLI (`sfllm sweep --axis <name>`).
    pub fn by_name(name: &str, values: &[f64]) -> Result<SweepAxis> {
        Ok(match name {
            "bandwidth" | "bandwidth_khz" => SweepAxis::bandwidth_khz(values),
            "client-compute" | "client_flops_per_cycle" => {
                SweepAxis::client_flops_per_cycle(values)
            }
            "server-compute" | "f_server_ghz" => SweepAxis::server_compute_ghz(values),
            "power" | "p_max_dbm" => SweepAxis::p_max_dbm(values),
            "clients" => SweepAxis::clients(values),
            "correlation" | "channel_rho" => SweepAxis::channel_correlation(values),
            "dropout" => SweepAxis::dropout(values),
            "reopt-period" | "reopt_period" => SweepAxis::reopt_period(values),
            "zeta" => SweepAxis::zeta(values),
            "lambda" => SweepAxis::lambda(values),
            other => bail!(
                "unknown sweep axis '{other}' (available: bandwidth, \
                 client-compute, server-compute, power, clients, \
                 correlation, dropout, reopt-period, zeta, lambda)"
            ),
        })
    }
}

impl std::fmt::Debug for SweepAxis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SweepAxis")
            .field("name", &self.name)
            .field("values", &self.values)
            .finish()
    }
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct PointResult {
    /// Axis coordinates, aligned with [`SweepReport::axis_names`].
    pub coords: Vec<f64>,
    /// Per-policy outcomes, aligned with [`SweepReport::policy_names`].
    pub outcomes: Vec<PolicyOutcome>,
}

impl PointResult {
    /// Objectives only, in policy order.
    pub fn objectives(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.objective).collect()
    }

    /// Total training energies (J), in policy order.
    pub fn energies(&self) -> Vec<f64> {
        self.outcomes.iter().map(|o| o.energy).collect()
    }
}

/// A grid point that could not be evaluated — e.g. a `clients` axis
/// value exceeding the subchannel count, or a policy failing on a
/// degenerate scenario. Recorded instead of failing the whole sweep.
#[derive(Clone, Debug)]
pub struct PointError {
    /// Index of the failing point in the cartesian grid (distinguishes
    /// points even when duplicate axis values give identical coords).
    pub point: usize,
    /// Axis coordinates of the failing point.
    pub coords: Vec<f64>,
    /// The policy that failed, or `None` when the scenario itself
    /// could not be built.
    pub policy: Option<String>,
    pub message: String,
}

/// Structured result of a sweep run. `points` holds the grid points
/// that evaluated successfully (in grid order); `errors` holds the
/// rest, also in grid order. CSV output contains only `points`; JSON
/// carries both.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub axis_names: Vec<String>,
    pub policy_names: Vec<String>,
    pub points: Vec<PointResult>,
    pub errors: Vec<PointError>,
    /// Whether the CSV surface carries per-policy `<name>:energy`
    /// columns next to the objective columns (set via
    /// [`SweepRunner::report_energy`]; JSON always carries delay and
    /// energy).
    pub energy_columns: bool,
}

impl SweepReport {
    /// CSV header: axis columns, one objective column per policy, and —
    /// when energy reporting is on — one `<policy>:energy` column per
    /// policy.
    pub fn header(&self) -> Vec<String> {
        let mut h: Vec<String> = self
            .axis_names
            .iter()
            .chain(self.policy_names.iter())
            .cloned()
            .collect();
        if self.energy_columns {
            h.extend(self.policy_names.iter().map(|n| format!("{n}:energy")));
        }
        h
    }

    /// The full report as a CSV string (used by the determinism test;
    /// [`SweepReport::write_csv`] emits exactly these bytes). Header
    /// fields are escaped like [`crate::util::csv::CsvWriter`] escapes
    /// them; numeric rows never need quoting.
    pub fn to_csv_string(&self) -> String {
        let header: Vec<String> = self.header().iter().map(|f| escape_field(f)).collect();
        let mut s = header.join(",");
        s.push('\n');
        for p in &self.points {
            let energies = if self.energy_columns {
                p.energies()
            } else {
                Vec::new()
            };
            let row: Vec<String> = p
                .coords
                .iter()
                .chain(p.objectives().iter())
                .chain(energies.iter())
                .map(|v| format!("{v}"))
                .collect();
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    /// Write the CSV — exactly the [`SweepReport::to_csv_string`] bytes;
    /// parent directories are created as needed.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        ensure_parent_dir(path)?;
        std::fs::write(path, self.to_csv_string())
            .with_context(|| format!("writing {path}"))
    }

    /// The report as a JSON string, including each policy's chosen
    /// split/rank (richer than the CSV objectives).
    pub fn to_json_string(&self) -> String {
        fn jstr(s: &str) -> String {
            let mut out = String::with_capacity(s.len() + 2);
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    // error messages can carry arbitrary control chars;
                    // escape them so the report stays spec-valid JSON
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32));
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
            out
        }
        fn jnum(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "null".to_string()
            }
        }
        let axes: Vec<String> = self.axis_names.iter().map(|s| jstr(s)).collect();
        let pols: Vec<String> = self.policy_names.iter().map(|s| jstr(s)).collect();
        let mut points = Vec::with_capacity(self.points.len());
        for p in &self.points {
            let coords: Vec<String> = self
                .axis_names
                .iter()
                .zip(&p.coords)
                .map(|(n, v)| format!("{}: {}", jstr(n), jnum(*v)))
                .collect();
            let outcomes: Vec<String> = p
                .outcomes
                .iter()
                .map(|o| {
                    format!(
                        "{}: {{\"objective\": {}, \"delay\": {}, \"energy\": {}, \
                         \"l_c\": {}, \"rank\": {}, \"iterations\": {}}}",
                        jstr(&o.policy),
                        jnum(o.objective),
                        jnum(o.delay),
                        jnum(o.energy),
                        o.alloc.l_c,
                        o.alloc.rank,
                        o.iterations
                    )
                })
                .collect();
            points.push(format!(
                "{{\"coords\": {{{}}}, \"policies\": {{{}}}}}",
                coords.join(", "),
                outcomes.join(", ")
            ));
        }
        let errors: Vec<String> = self
            .errors
            .iter()
            .map(|e| {
                let coords: Vec<String> = self
                    .axis_names
                    .iter()
                    .zip(&e.coords)
                    .map(|(n, v)| format!("{}: {}", jstr(n), jnum(*v)))
                    .collect();
                format!(
                    "{{\"point\": {}, \"coords\": {{{}}}, \"policy\": {}, \"message\": {}}}",
                    e.point,
                    coords.join(", "),
                    e.policy.as_deref().map(jstr).unwrap_or_else(|| "null".to_string()),
                    jstr(&e.message)
                )
            })
            .collect();
        format!(
            "{{\n  \"axes\": [{}],\n  \"policies\": [{}],\n  \"points\": [\n    {}\n  ],\n  \"errors\": [{}]\n}}\n",
            axes.join(", "),
            pols.join(", "),
            points.join(",\n    "),
            errors.join(", ")
        )
    }

    /// Write the JSON report (parent directories are created as needed).
    pub fn write_json(&self, path: &str) -> Result<()> {
        ensure_parent_dir(path)?;
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing {path}"))
    }

    /// Pretty console table; adds a reduction column when both
    /// `proposed` and `baseline_a` are present (the paper's headline
    /// "up to 60% lower than random" comparison).
    pub fn print_table(&self) {
        let prop = self.policy_names.iter().position(|n| n == "proposed");
        let base_a = self.policy_names.iter().position(|n| n == "baseline_a");
        let with_reduction = prop.is_some() && base_a.is_some();
        for name in &self.axis_names {
            print!("{name:>24} ");
        }
        for name in &self.policy_names {
            print!("{name:>12} ");
        }
        if with_reduction {
            print!("{:>10}", "red. vs a");
        }
        println!();
        for p in &self.points {
            for v in &p.coords {
                print!("{v:>24.2} ");
            }
            let obj = p.objectives();
            for v in &obj {
                print!("{v:>12.1} ");
            }
            if let (Some(ip), Some(ia)) = (prop, base_a) {
                print!("{:>9.0}%", 100.0 * (1.0 - obj[ip] / obj[ia]));
            }
            println!();
        }
        self.print_errors();
    }

    /// Print one line per error row — the single rendering of
    /// [`PointError`]s shared by [`SweepReport::print_table`] and the
    /// CLI/example surfaces.
    pub fn print_errors(&self) {
        for e in &self.errors {
            println!(
                "  ! point {:?} skipped ({}): {}",
                e.coords,
                e.policy.as_deref().unwrap_or("scenario"),
                e.message
            );
        }
    }

    /// Number of distinct grid points that produced error rows (a point
    /// with several failing policies yields several rows but counts
    /// once; rows for one point are adjacent, in grid order).
    pub fn skipped_points(&self) -> usize {
        let mut skipped = 0;
        let mut last = None;
        for e in &self.errors {
            if last != Some(e.point) {
                skipped += 1;
                last = Some(e.point);
            }
        }
        skipped
    }
}

/// Declarative sweep executor. See the module docs for the contract.
pub struct SweepRunner {
    base: Config,
    conv: ConvergenceModel,
    axes: Vec<SweepAxis>,
    policies: Vec<Arc<dyn AllocationPolicy>>,
    threads: usize,
    energy_columns: bool,
}

impl SweepRunner {
    /// Start from a scenario builder (its config is the sweep base).
    pub fn new(base: &ScenarioBuilder) -> SweepRunner {
        SweepRunner {
            base: base.config().clone(),
            conv: ConvergenceModel::paper_default(),
            axes: Vec::new(),
            policies: Vec::new(),
            threads: 0,
            energy_columns: false,
        }
    }

    /// Add a sweep axis; multiple axes form a cartesian grid (later
    /// axes vary fastest). With no axes the sweep is a single point.
    pub fn over(mut self, axis: SweepAxis) -> SweepRunner {
        self.axes.push(axis);
        self
    }

    /// The policies to evaluate at every grid point (report columns,
    /// in order). Usually `registry.resolve("all")?`.
    pub fn policies(mut self, policies: Vec<Arc<dyn AllocationPolicy>>) -> SweepRunner {
        self.policies = policies;
        self
    }

    /// Override the convergence model E(r) (default: paper fit).
    pub fn convergence(mut self, conv: ConvergenceModel) -> SweepRunner {
        self.conv = conv;
        self
    }

    /// Worker thread count; 0 (default) means all available cores.
    pub fn threads(mut self, n: usize) -> SweepRunner {
        self.threads = n;
        self
    }

    /// Add per-policy `<name>:energy` columns to the CSV surface
    /// (default off, keeping legacy report shapes byte-stable; the JSON
    /// report always carries delay and energy).
    pub fn report_energy(mut self, on: bool) -> SweepRunner {
        self.energy_columns = on;
        self
    }

    fn grid(&self) -> Vec<Vec<f64>> {
        let mut grid: Vec<Vec<f64>> = vec![Vec::new()];
        for axis in &self.axes {
            let mut next = Vec::with_capacity(grid.len() * axis.values.len());
            for point in &grid {
                for &v in &axis.values {
                    let mut p = point.clone();
                    p.push(v);
                    next.push(p);
                }
            }
            grid = next;
        }
        grid
    }

    /// Evaluate one grid point: apply the axis values, sample the
    /// scenario, run every policy against the shared workload cache.
    /// Failures become [`PointError`] rows rather than aborting the
    /// sweep — a grid is allowed to contain infeasible corners (e.g. a
    /// `clients` value exceeding the subchannel count). Every policy is
    /// attempted even after one fails, so each failing policy gets its
    /// own error row; a point with any failure is dropped from
    /// [`SweepReport::points`] as a whole, because a `PointResult` (and
    /// its CSV row) must carry one outcome per policy column.
    fn run_point(
        &self,
        point: usize,
        coords: &[f64],
        cache: &WorkloadCache,
    ) -> Result<PointResult, Vec<PointError>> {
        let mut cfg = self.base.clone();
        for (axis, &v) in self.axes.iter().zip(coords) {
            (axis.apply)(&mut cfg, v);
        }
        let scn = match ScenarioBuilder::from_config(cfg).build() {
            Ok(scn) => scn,
            Err(e) => {
                return Err(vec![PointError {
                    point,
                    coords: coords.to_vec(),
                    policy: None,
                    message: format!("{e:#}"),
                }])
            }
        };
        let mut outcomes = Vec::with_capacity(self.policies.len());
        let mut errors = Vec::new();
        for policy in &self.policies {
            match policy.solve_cached(&scn, &self.conv, cache) {
                Ok(outcome) => outcomes.push(outcome),
                Err(e) => errors.push(PointError {
                    point,
                    coords: coords.to_vec(),
                    policy: Some(policy.name().to_string()),
                    message: format!("{e:#}"),
                }),
            }
        }
        if errors.is_empty() {
            Ok(PointResult {
                coords: coords.to_vec(),
                outcomes,
            })
        } else {
            Err(errors)
        }
    }

    /// Run the whole grid and collect the report. Points are fanned out
    /// across worker threads but written back by grid index, so the
    /// report (and its CSV/JSON serializations) is independent of the
    /// thread count. All points share one [`WorkloadCache`], so grid
    /// points with the same model/sequence/rank set reuse the cached
    /// workload tables. Infeasible points land in
    /// [`SweepReport::errors`]; `Err` is reserved for misuse of the
    /// runner itself (no policies, an empty axis).
    pub fn run(&self) -> Result<SweepReport> {
        if self.policies.is_empty() {
            bail!("sweep has no policies (use .policies(registry.resolve(..)?))");
        }
        for axis in &self.axes {
            if axis.values.is_empty() {
                bail!("sweep axis '{}' has no values", axis.name);
            }
        }
        let grid = self.grid();
        let jobs = grid.len();
        let workers = if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
        .min(jobs)
        .max(1);

        let cache = WorkloadCache::new();
        let mut slots: Vec<Option<Result<PointResult, Vec<PointError>>>> = Vec::with_capacity(jobs);
        if workers == 1 {
            for (i, coords) in grid.iter().enumerate() {
                slots.push(Some(self.run_point(i, coords, &cache)));
            }
        } else {
            slots.resize_with(jobs, || None);
            let results = Mutex::new(&mut slots);
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs {
                            break;
                        }
                        let res = self.run_point(i, &grid[i], &cache);
                        // lint:allow(P101) lock poisoning implies a sibling worker already panicked
                        results.lock().expect("sweep results lock")[i] = Some(res);
                    });
                }
            });
        }

        let mut points = Vec::with_capacity(jobs);
        let mut errors = Vec::new();
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.ok_or_else(|| anyhow!("sweep point {i} never ran"))? {
                Ok(point) => points.push(point),
                Err(es) => errors.extend(es),
            }
        }
        Ok(SweepReport {
            axis_names: self.axes.iter().map(|a| a.name.clone()).collect(),
            policy_names: self.policies.iter().map(|p| p.name().to_string()).collect(),
            points,
            errors,
            energy_columns: self.energy_columns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::PolicyRegistry;

    fn tiny_base() -> ScenarioBuilder {
        // 2 clients, short sequence: keeps BCD cheap in unit tests
        ScenarioBuilder::new()
            .clients(2)
            .tweak(|c| c.train.seq = 128)
    }

    fn reg() -> PolicyRegistry {
        PolicyRegistry::paper_suite(&[1, 4], 11, 1)
    }

    #[test]
    fn single_point_sweep_with_no_axes() {
        let report = SweepRunner::new(&tiny_base())
            .policies(reg().resolve("proposed").unwrap())
            .threads(1)
            .run()
            .unwrap();
        assert_eq!(report.points.len(), 1);
        assert!(report.points[0].coords.is_empty());
        assert_eq!(report.policy_names, vec!["proposed"]);
        assert!(report.points[0].outcomes[0].objective > 0.0);
    }

    #[test]
    fn cartesian_grid_enumerates_all_combinations() {
        let report = SweepRunner::new(&tiny_base())
            .over(SweepAxis::bandwidth_khz(&[250.0, 500.0]))
            .over(SweepAxis::p_max_dbm(&[30.0, 35.0, 40.0]))
            .policies(reg().resolve("baseline_a").unwrap())
            .threads(2)
            .run()
            .unwrap();
        assert_eq!(report.points.len(), 6);
        // later axis varies fastest
        assert_eq!(report.points[0].coords, vec![250.0, 30.0]);
        assert_eq!(report.points[1].coords, vec![250.0, 35.0]);
        assert_eq!(report.points[3].coords, vec![500.0, 30.0]);
        assert_eq!(report.header(), vec!["bandwidth_khz", "p_max_dbm", "baseline_a"]);
    }

    #[test]
    fn csv_shape_matches_grid() {
        let report = SweepRunner::new(&tiny_base())
            .over(SweepAxis::server_compute_ghz(&[5.0, 10.0]))
            .policies(reg().resolve("all").unwrap())
            .threads(1)
            .run()
            .unwrap();
        let csv = report.to_csv_string();
        let lines: Vec<&str> = csv.trim_end().lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(
            lines[0],
            "f_server_ghz,proposed,baseline_a,baseline_b,baseline_c,baseline_d"
        );
        assert_eq!(lines[1].split(',').count(), 6);
    }

    #[test]
    fn empty_policy_list_is_an_error() {
        let err = SweepRunner::new(&tiny_base()).threads(1).run().unwrap_err();
        assert!(format!("{err}").contains("no policies"));
    }

    #[test]
    fn infeasible_grid_point_becomes_error_row() {
        // 25 clients exceed the paper preset's 20 subchannels per link
        let report = SweepRunner::new(&tiny_base())
            .over(SweepAxis::clients(&[2.0, 25.0, 3.0]))
            .policies(reg().resolve("proposed").unwrap())
            .threads(2)
            .run()
            .unwrap();
        assert_eq!(report.points.len(), 2);
        assert_eq!(report.points[0].coords, vec![2.0]);
        assert_eq!(report.points[1].coords, vec![3.0]);
        assert_eq!(report.errors.len(), 1);
        let e = &report.errors[0];
        assert_eq!(e.coords, vec![25.0]);
        assert!(e.policy.is_none(), "scenario build failed, not a policy");
        assert!(e.message.contains("subchannel"), "{}", e.message);
        // CSV carries only the feasible rows
        assert_eq!(report.to_csv_string().trim_end().lines().count(), 1 + 2);
        // JSON carries the error row too
        let json = report.to_json_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let errs = parsed.get("errors").unwrap().as_arr().unwrap();
        assert_eq!(errs.len(), 1);
        assert!(errs[0]
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("subchannel"));
    }

    #[test]
    fn every_failing_policy_gets_its_own_error_row() {
        struct Failing(&'static str);
        impl AllocationPolicy for Failing {
            fn name(&self) -> &str {
                self.0
            }
            fn solve_cached(
                &self,
                _scn: &crate::delay::Scenario,
                _conv: &ConvergenceModel,
                _cache: &WorkloadCache,
            ) -> Result<PolicyOutcome> {
                anyhow::bail!("deliberate {} failure", self.0)
            }
        }
        let mut policies = reg().resolve("proposed").unwrap();
        policies.push(Arc::new(Failing("fail_x")));
        policies.push(Arc::new(Failing("fail_y")));
        // duplicate axis value on purpose: the two grid points share
        // coords and must still count as two skipped points
        let report = SweepRunner::new(&tiny_base())
            .over(SweepAxis::clients(&[2.0, 2.0]))
            .policies(policies)
            .threads(1)
            .run()
            .unwrap();
        // both failing policies are diagnosed at both points; and since a
        // CSV row needs every policy column, the points carry no rows
        assert!(report.points.is_empty());
        assert_eq!(report.errors.len(), 4);
        assert_eq!(report.skipped_points(), 2, "rows per point must collapse to one");
        assert_eq!(report.errors[0].policy.as_deref(), Some("fail_x"));
        assert_eq!(report.errors[1].policy.as_deref(), Some("fail_y"));
        assert_eq!(report.errors[0].point, 0);
        assert_eq!(report.errors[2].point, 1);
        assert_eq!(report.errors[0].coords, vec![2.0]);
        assert_eq!(report.errors[2].coords, vec![2.0]);
        assert!(report.errors[0].message.contains("fail_x failure"));
    }

    #[test]
    fn json_escapes_control_characters_in_error_messages() {
        let report = SweepReport {
            axis_names: vec!["x".into()],
            policy_names: vec!["proposed".into()],
            points: vec![],
            errors: vec![PointError {
                point: 0,
                coords: vec![1.0],
                policy: None,
                message: "tab\there\rdone".into(),
            }],
            energy_columns: false,
        };
        let json = report.to_json_string();
        assert!(!json.contains('\t'), "raw control char leaked into JSON");
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let msg = parsed.get("errors").unwrap().as_arr().unwrap()[0]
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .to_string();
        assert_eq!(msg, "tab\there\rdone");
    }

    #[test]
    fn error_rows_are_deterministic_across_thread_counts() {
        let run = |threads: usize| {
            SweepRunner::new(&tiny_base())
                .over(SweepAxis::clients(&[25.0, 2.0, 30.0]))
                .policies(reg().resolve("proposed").unwrap())
                .threads(threads)
                .run()
                .unwrap()
        };
        let a = run(1);
        let b = run(3);
        assert_eq!(a.to_csv_string(), b.to_csv_string());
        assert_eq!(a.errors.len(), b.errors.len());
        for (x, y) in a.errors.iter().zip(&b.errors) {
            assert_eq!(x.coords, y.coords);
            assert_eq!(x.message, y.message);
        }
    }

    #[test]
    fn axis_by_name_resolves_canned_axes() {
        for name in [
            "bandwidth",
            "client-compute",
            "server-compute",
            "power",
            "clients",
            "correlation",
            "dropout",
            "reopt-period",
        ] {
            assert!(SweepAxis::by_name(name, &[1.0]).is_ok(), "{name}");
        }
        assert!(SweepAxis::by_name("nope", &[1.0]).is_err());
    }

    #[test]
    fn dynamics_axes_write_the_dynamics_config() {
        let mut cfg = Config::paper_defaults();
        (SweepAxis::channel_correlation(&[0.6]).apply)(&mut cfg, 0.6);
        (SweepAxis::dropout(&[0.1]).apply)(&mut cfg, 0.1);
        (SweepAxis::reopt_period(&[4.0]).apply)(&mut cfg, 4.0);
        assert_eq!(cfg.dynamics.rho, 0.6);
        assert_eq!(cfg.dynamics.dropout, 0.1);
        assert_eq!(cfg.dynamics.strategy, "periodic:4");
        (SweepAxis::reopt_period(&[0.0]).apply)(&mut cfg, 0.0);
        assert_eq!(cfg.dynamics.strategy, "periodic:1", "J clamps to >= 1");
    }

    #[test]
    fn energy_axes_write_the_objective_config() {
        let mut cfg = Config::paper_defaults();
        (SweepAxis::zeta(&[2e-28]).apply)(&mut cfg, 2e-28);
        assert_eq!(cfg.objective.zeta, 2e-28);
        (SweepAxis::lambda(&[0.05]).apply)(&mut cfg, 0.05);
        assert_eq!(cfg.objective.kind, "weighted");
        assert_eq!(cfg.objective.lambda, 0.05);
    }

    #[test]
    fn energy_columns_extend_csv_and_json_always_carries_energy() {
        let report = SweepRunner::new(&tiny_base())
            .over(SweepAxis::lambda(&[0.0, 0.01]))
            .policies(reg().resolve("proposed").unwrap())
            .threads(1)
            .report_energy(true)
            .run()
            .unwrap();
        assert_eq!(report.header(), vec!["lambda", "proposed", "proposed:energy"]);
        let csv = report.to_csv_string();
        let lines: Vec<&str> = csv.trim_end().lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[1].split(',').count(), 3);
        // energy column carries the outcome's energy verbatim
        let e0: f64 = lines[1].split(',').nth(2).unwrap().parse().unwrap();
        assert_eq!(e0.to_bits(), report.points[0].outcomes[0].energy.to_bits());
        assert!(e0 > 0.0);
        // JSON: delay + energy present regardless of the CSV flag
        let json = report.to_json_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let p0 = &parsed.get("points").unwrap().as_arr().unwrap()[0];
        let pol = p0.get("policies").unwrap().get("proposed").unwrap();
        assert!(pol.get("energy").unwrap().as_f64().unwrap() > 0.0);
        assert!(pol.get("delay").unwrap().as_f64().unwrap() > 0.0);
        // at lambda = 0 the weighted objective IS the delay
        let p = &report.points[0].outcomes[0];
        assert_eq!(p.objective.to_bits(), p.delay.to_bits());
    }

    #[test]
    fn json_report_is_well_formed_enough() {
        let report = SweepRunner::new(&tiny_base())
            .over(SweepAxis::clients(&[2.0]))
            .policies(reg().resolve("proposed").unwrap())
            .threads(1)
            .run()
            .unwrap();
        let json = report.to_json_string();
        let parsed = crate::util::json::Json::parse(&json).unwrap();
        let pts = parsed.get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 1);
        let obj = pts[0]
            .get("policies")
            .unwrap()
            .get("proposed")
            .unwrap()
            .get("objective")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(obj > 0.0);
    }
}
