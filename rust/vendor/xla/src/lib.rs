//! Offline stub of the `xla` PJRT bindings.
//!
//! The build image ships no PJRT shared library and no network to fetch
//! the real `xla` crate, so this stub mirrors exactly the API surface
//! `sfllm::runtime` consumes and fails fast at the *first* entry point
//! ([`PjRtClient::cpu`]) with a clear message. Everything downstream of
//! a client is therefore unreachable in stub builds, but still
//! type-checks, so the whole training stack compiles and the
//! simulation/optimizer layers (which never touch PJRT) work fully.
//!
//! Swapping in a real PJRT backend is a one-line change in
//! `rust/Cargo.toml`: point the `xla` path dependency at the real
//! bindings. Runtime-dependent tests are gated behind the
//! `SFLLM_RUNTIME_TESTS=1` environment variable for the same reason.

use std::fmt;

/// Stub error: carries the "backend unavailable" explanation.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "PJRT backend unavailable in this build: {what} needs the real `xla` \
         bindings (swap the `xla` path dependency in rust/Cargo.toml; see \
         DESIGN.md, runtime section)"
    ))
}

/// PJRT client handle. In the stub, construction always fails.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = err.to_string();
        assert!(msg.contains("PJRT backend unavailable"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }
}
