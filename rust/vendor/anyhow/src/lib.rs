//! Offline, API-compatible subset of the `anyhow` error crate.
//!
//! The build image has no network access, so the real crates.io
//! `anyhow` cannot be fetched; this vendored drop-in implements exactly
//! the surface the sfllm crate uses:
//!
//! * [`Error`] — an opaque error value holding a context chain;
//! * [`Result<T>`] — alias with `Error` as the default error type;
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on both
//!   `Result` and `Option`;
//! * `From<E: std::error::Error>` so `?` converts library errors.
//!
//! Formatting matches the real crate where it matters: `{e}` prints the
//! outermost message, `{e:#}` prints the whole chain joined by `: `,
//! and `{e:?}` prints the message plus a `Caused by:` list.

use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Opaque error: an outermost message plus the chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost context, the last entry the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    fn push_context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket conversion coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

mod private {
    use super::Error;

    /// Sealed conversion used by [`super::Context`]: covers both
    /// `Error` itself and every std error type.
    pub trait IntoAnyhow {
        fn into_anyhow(self) -> Error;
    }

    impl IntoAnyhow for Error {
        fn into_anyhow(self) -> Error {
            self
        }
    }

    impl<E> IntoAnyhow for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_anyhow(self) -> Error {
            Error::from(self)
        }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: private::IntoAnyhow,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display,
    {
        self.map_err(|e| e.into_anyhow().push_context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_anyhow().push_context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Result::<(), _>::Err(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(1).context("unused").unwrap(), 1);
    }

    #[test]
    fn macros_build_errors() {
        fn f(flag: bool) -> Result<()> {
            if flag {
                bail!("flag was {flag}");
            }
            Ok(())
        }
        assert_eq!(format!("{}", f(true).unwrap_err()), "flag was true");
        let e = anyhow!("x = {}", 7);
        assert_eq!(format!("{e}"), "x = 7");
        let e2 = anyhow!(String::from("owned"));
        assert_eq!(format!("{e2}"), "owned");
    }

    #[test]
    fn context_stacks_outermost_first() {
        let e = Result::<(), _>::Err(io_err())
            .context("layer 1")
            .with_context(|| format!("layer {}", 2))
            .unwrap_err();
        assert_eq!(format!("{e}"), "layer 2");
        assert_eq!(format!("{e:#}"), "layer 2: layer 1: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }
}
