//! Properties of the event-driven population engine (`sim::population`):
//! the degenerate-population anchor invariant against [`RoundSimulator`]
//! on **every** preset, cohort-selection determinism, and the O(1)
//! lazy-advance closed form.

use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::net::ar1_jump;
use sfllm::opt::policy::Proposed;
use sfllm::sim::{
    Population, PopulationSimulator, PopulationState, ReOptStrategy, RoundSimulator,
    ScenarioBuilder, PRESETS,
};

const RANKS: [usize; 2] = [1, 4];

fn short_conv() -> ConvergenceModel {
    ConvergenceModel::fitted(4.0, 1.0, 0.85)
}

/// The preset's config shrunk to test size: tiny model, two ranks, and
/// K clamped so the debug-mode solver stays fast. Everything else —
/// links, objective, dynamics — is the preset's own.
fn preset_config(preset: &str) -> sfllm::config::Config {
    let mut cfg = ScenarioBuilder::preset(preset).unwrap().into_config();
    cfg.model = "tiny".to_string();
    cfg.train.seq = 64;
    cfg.train.ranks = RANKS.to_vec();
    cfg.system.clients = cfg.system.clients.min(8);
    cfg
}

/// Degenerate the population: population == K, full-participation
/// selection, no straggler deadline.
fn degenerate(cfg: &mut sfllm::config::Config) {
    cfg.population.size = cfg.system.clients;
    cfg.population.cohort = cfg.system.clients;
    cfg.population.selector = "uniform".to_string();
    cfg.population.deadline_drop = 0.0;
}

#[test]
fn degenerate_population_matches_round_simulator_on_every_preset() {
    // The anchor invariant: with population == K, a full-participation
    // selector, and no deadline, the population engine IS the round
    // simulator — bit for bit, on every preset (frozen and dynamic,
    // delay and energy objectives alike).
    let conv = short_conv();
    for preset in PRESETS {
        let mut cfg = preset_config(preset);
        degenerate(&mut cfg);
        let pop = Population::new(&cfg).unwrap();
        let scn = pop.scenario().unwrap();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let rs = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let ps = PopulationSimulator::new(&pop, &conv, &cache, &RANKS);
        for strat in [ReOptStrategy::OneShot, ReOptStrategy::Periodic(2)] {
            let a = rs.run(&policy, strat).unwrap();
            let b = ps.run(&policy, strat).unwrap();
            let tag = format!("{preset}/{}", strat.label());
            assert_eq!(
                a.realized_delay.to_bits(),
                b.realized_delay.to_bits(),
                "realized delay drifted on {tag}"
            );
            assert_eq!(
                a.realized_energy.to_bits(),
                b.realized_energy.to_bits(),
                "realized energy drifted on {tag}"
            );
            assert_eq!(
                a.static_prediction.to_bits(),
                b.static_prediction.to_bits(),
                "static prediction drifted on {tag}"
            );
            assert_eq!(a.resolves, b.resolves, "resolves drifted on {tag}");
            assert_eq!(a.fresh_solves, b.fresh_solves, "fresh solves drifted on {tag}");
            assert_eq!(a.rounds.len(), b.rounds.len(), "round count drifted on {tag}");
            assert_eq!(b.deadline_drops, 0, "no deadline configured on {tag}");
            for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
                assert_eq!(ra.delay.to_bits(), rb.delay.to_bits(), "round delay on {tag}");
                assert_eq!(ra.energy.to_bits(), rb.energy.to_bits(), "round energy on {tag}");
                assert_eq!(ra.weight.to_bits(), rb.weight.to_bits(), "round weight on {tag}");
                assert_eq!(
                    (ra.l_c, ra.rank, ra.active, ra.resolved, ra.cohort, ra.dropped),
                    (rb.l_c, rb.rank, rb.active, rb.resolved, rb.cohort, rb.dropped),
                    "round shape on {tag}"
                );
            }
        }
    }
}

#[test]
fn cohort_selection_is_deterministic_across_fresh_states() {
    // Same seed, fresh state → the same cohort sequence, for every
    // selector family.
    for selector in ["uniform", "weighted", "staleness:3"] {
        let mut cfg = ScenarioBuilder::preset("metro_population")
            .unwrap()
            .into_config();
        cfg.model = "tiny".to_string();
        cfg.train.seq = 64;
        cfg.population.size = 5_000;
        cfg.population.cohort = 32;
        cfg.population.selector = selector.to_string();
        let pop = Population::new(&cfg).unwrap();
        let mut s1 = PopulationState::new(pop.size());
        let mut s2 = PopulationState::new(pop.size());
        for round in 0..6 {
            let c1 = pop.select(&mut s1, round);
            let c2 = pop.select(&mut s2, round);
            assert_eq!(c1, c2, "selector {selector} diverged at round {round}");
            assert_eq!(c1.len(), 32, "selector {selector} cohort size");
            for &i in &c1 {
                assert!(i < pop.size(), "selector {selector} picked client {i}");
            }
        }
    }
}

#[test]
fn observations_are_schedule_independent_across_clients_and_o1_in_the_gap() {
    // Client i's observed trajectory depends only on i's own observation
    // schedule — never on which other clients were observed in between —
    // and a 100k-round gap is one closed-form jump, not 100k steps.
    let mut cfg = ScenarioBuilder::preset("metro_population")
        .unwrap()
        .into_config();
    cfg.model = "tiny".to_string();
    cfg.train.seq = 64;
    cfg.population.size = 10_000;
    let pop = Population::new(&cfg).unwrap();

    // alone vs interleaved with hundreds of other clients
    let mut lone = PopulationState::new(pop.size());
    let mut busy = PopulationState::new(pop.size());
    for round in [0usize, 3, 7, 20] {
        let a = pop.observe(&mut lone, 42, round);
        for other in 0..200 {
            pop.observe(&mut busy, other, round);
        }
        let b = pop.observe(&mut busy, 42, round);
        assert_eq!(a.gain_main.to_bits(), b.gain_main.to_bits(), "round {round}");
        assert_eq!(a.gain_fed.to_bits(), b.gain_fed.to_bits(), "round {round}");
        assert_eq!(a.f_cycles.to_bits(), b.f_cycles.to_bits(), "round {round}");
        assert_eq!(a.online, b.online, "round {round}");
    }

    // a huge gap lands in O(1): same jump, same bits, twice
    let mut g1 = PopulationState::new(pop.size());
    let mut g2 = PopulationState::new(pop.size());
    let o1 = pop.observe(&mut g1, 7, 100_000);
    let o2 = pop.observe(&mut g2, 7, 100_000);
    assert!(o1.gain_main.is_finite() && o1.gain_main > 0.0);
    assert_eq!(o1.gain_main.to_bits(), o2.gain_main.to_bits());
    assert_eq!(o1.gain_fed.to_bits(), o2.gain_fed.to_bits());
}

#[test]
fn ar1_jump_composes_and_degenerates_exactly() {
    // gap = 1 must return the eager step's own coefficients bit-for-bit
    // (that is what makes the anchor invariant possible at all) ...
    let (rho, sigma) = (0.8f64, 7.9f64);
    let (r1, s1) = ar1_jump(rho, sigma, 1);
    assert_eq!(r1.to_bits(), rho.to_bits());
    assert_eq!(s1.to_bits(), ((1.0 - rho * rho).max(0.0).sqrt() * sigma).to_bits());
    // ... gap = 0 is the identity ...
    assert_eq!(ar1_jump(rho, sigma, 0), (1.0, 0.0));
    // ... and a jump over a+b rounds is the composition of a jump over
    // a then b: rho multiplies, variances fold as sigma_ab^2 =
    // sigma_b^2 + rho_b^2 * sigma_a^2 (to rounding).
    for (a, b) in [(1u64, 1u64), (2, 3), (10, 17), (1000, 4242)] {
        let (ra, sa) = ar1_jump(rho, sigma, a);
        let (rb, sb) = ar1_jump(rho, sigma, b);
        let (rab, sab) = ar1_jump(rho, sigma, a + b);
        assert!((rab - ra * rb).abs() < 1e-12, "rho composition at ({a},{b})");
        let folded = (sb * sb + rb * rb * sa * sa).sqrt();
        assert!(
            (sab - folded).abs() < 1e-9,
            "variance composition at ({a},{b}): {sab} vs {folded}"
        );
    }
    // rho = 1 freezes the process at any gap
    let (rf, sf) = ar1_jump(1.0, sigma, 12_345);
    assert_eq!(rf, 1.0);
    assert_eq!(sf, 0.0);
}
