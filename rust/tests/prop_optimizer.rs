//! Property tests over the Section-VI optimizer: seeded random
//! scenarios, structural invariants checked on every case.
//!
//! (Own property harness — `sfllm::util::prop` — since proptest is not
//! in the offline crate set. Failures print a standalone replay seed.)

use sfllm::config::Config;
use sfllm::delay::{ConvergenceModel, Scenario};
use sfllm::opt::assignment::algorithm2;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::opt::power::{solve_power, solve_power_hinted, waterfill_min_power, PowerScratch};
use sfllm::opt::{baselines, rank, split};
use sfllm::sim::ScenarioBuilder;
use sfllm::util::prop::check;
use sfllm::util::rng::Rng;

/// Random but sane scenario drawn from the paper's parameter ranges.
fn random_scenario(rng: &mut Rng) -> Scenario {
    let mut cfg = Config::paper_defaults();
    cfg.system.clients = 2 + rng.below(5); // 2..=6
    cfg.system.subch_main = cfg.system.clients + rng.below(16);
    cfg.system.subch_fed = cfg.system.clients + rng.below(16);
    cfg.system.bandwidth_main_hz = rng.range(100e3, 2e6);
    cfg.system.bandwidth_fed_hz = rng.range(100e3, 2e6);
    cfg.system.f_server = rng.range(2e9, 2e10);
    cfg.system.d_main_m = rng.range(50.0, 300.0);
    cfg.system.seed = rng.next_u64();
    cfg.train.batch = 1 + rng.below(32);
    cfg.train.seq = 128 << rng.below(3);
    cfg.model = if rng.f64() < 0.5 { "gpt2-s" } else { "gpt2-m" }.into();
    ScenarioBuilder::from_config(cfg).build().expect("scenario build")
}

const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

#[test]
fn prop_assignment_satisfies_c1_c2() {
    check("assignment C1/C2", 0xA11, 40, |rng| {
        let scn = random_scenario(rng);
        let l_c = 1 + rng.below(scn.profile.blocks.len() - 1);
        let r = *rng.choose(&RANKS);
        let a = algorithm2(&scn, l_c, r);
        // exclusivity + completeness on both links
        for (assign, m) in [
            (&a.assign_main, scn.main_link.subch.len()),
            (&a.assign_fed, scn.fed_link.subch.len()),
        ] {
            let mut owners = vec![0usize; m];
            for subs in assign.iter() {
                for &i in subs {
                    if i >= m {
                        return Err(format!("subchannel {i} out of range"));
                    }
                    owners[i] += 1;
                }
            }
            if owners.iter().any(|&c| c != 1) {
                return Err(format!("ownership counts {owners:?}"));
            }
        }
        // every client served on both links (K <= M, N by construction)
        for k in 0..scn.k() {
            if a.assign_main[k].is_empty() || a.assign_fed[k].is_empty() {
                return Err(format!("client {k} starved"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_waterfilling_beats_random_splits() {
    check("water-filling optimality", 0xBEEF, 30, |rng| {
        let scn = random_scenario(rng);
        let link = &scn.main_link;
        let n_sub = 2 + rng.below(4.min(link.subch.len() - 1));
        let subs: Vec<usize> = (0..n_sub).collect();
        let rate = rng.range(1e4, 5e6);
        let (p_star, _) = waterfill_min_power(link, 0, &subs, rate);
        if !p_star.is_finite() {
            return Ok(()); // unreachable rate: nothing to verify
        }
        // random rate splits achieving the same total may not use less power
        for _ in 0..20 {
            let mut weights: Vec<f64> = (0..n_sub).map(|_| rng.range(0.05, 1.0)).collect();
            let sum: f64 = weights.iter().sum();
            weights.iter_mut().for_each(|w| *w *= rate / sum);
            let p: f64 = subs
                .iter()
                .zip(&weights)
                .map(|(&i, &ri)| link.power_w(i, link.psd_for_rate(0, i, ri)))
                .sum();
            if p < p_star * (1.0 - 1e-9) {
                return Err(format!("random split used {p} < waterfill {p_star}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_power_solution_feasible_and_tight() {
    check("P2 feasibility/tightness", 0xCAFE, 25, |rng| {
        let scn = random_scenario(rng);
        let l_c = 1 + rng.below(scn.profile.blocks.len() - 1);
        let r = *rng.choose(&RANKS);
        let a = algorithm2(&scn, l_c, r);
        let mut alloc = sfllm::delay::Allocation {
            assign_main: a.assign_main,
            assign_fed: a.assign_fed,
            psd_main: vec![0.0; scn.main_link.subch.len()],
            psd_fed: vec![0.0; scn.fed_link.subch.len()],
            l_c,
            rank: r,
        };
        let sol = solve_power(&scn, &alloc).map_err(|e| e.to_string())?;
        alloc.psd_main = sol.psd_main;
        alloc.psd_fed = sol.psd_fed;
        // C4/C5 hold
        if !scn.power_feasible(&alloc, 1e-6) {
            return Err("power constraints violated".into());
        }
        // T1 is achieved: max_k (T_k^F + T_k^s) == t1
        let ph = scn.phase_delays(&alloc);
        let worst = ph
            .client_fwd
            .iter()
            .zip(&ph.act_upload)
            .map(|(a, b)| a + b)
            .fold(0.0f64, f64::max);
        if (worst - sol.t1).abs() / sol.t1.max(1e-12) > 1e-3 {
            return Err(format!("t1 {} but achieved {}", sol.t1, worst));
        }
        Ok(())
    });
}

#[test]
fn prop_warm_started_p2_is_bit_identical_for_any_hint() {
    // solve_power_hinted's monotone-skip warm start must never move a
    // bit of the solution — for the previous optimum (the BCD hint),
    // for garbage hints, for non-finite hints — and scratch reuse
    // across solves must be equally invisible.
    check("P2 warm-start bit-identity", 0x9A9A, 20, |rng| {
        let scn = random_scenario(rng);
        let l_c = 1 + rng.below(scn.profile.blocks.len() - 1);
        let r = *rng.choose(&RANKS);
        let a = algorithm2(&scn, l_c, r);
        let alloc = sfllm::delay::Allocation {
            assign_main: a.assign_main,
            assign_fed: a.assign_fed,
            psd_main: vec![0.0; scn.main_link.subch.len()],
            psd_fed: vec![0.0; scn.fed_link.subch.len()],
            l_c,
            rank: r,
        };
        let cold = solve_power(&scn, &alloc).map_err(|e| e.to_string())?;
        let mut scratch = PowerScratch::default();
        let hints = [
            None,
            Some((cold.t1, cold.t3)),
            Some((cold.t1 * (1.0 + 1e-9), cold.t3 * (1.0 - 1e-9))),
            Some((cold.t1 * 0.25, cold.t3 * 8.0)),
            Some((rng.range(1e-9, 1e4), rng.range(1e-9, 1e4))),
            Some((f64::NAN, f64::INFINITY)),
            Some((0.0, -1.0)),
        ];
        for hint in hints {
            let warm =
                solve_power_hinted(&scn, &alloc, hint, &mut scratch).map_err(|e| e.to_string())?;
            if warm.t1.to_bits() != cold.t1.to_bits() || warm.t3.to_bits() != cold.t3.to_bits() {
                return Err(format!(
                    "hint {hint:?} moved T*: ({}, {}) vs ({}, {})",
                    warm.t1, warm.t3, cold.t1, cold.t3
                ));
            }
            for (x, y) in warm.psd_main.iter().zip(&cold.psd_main) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("hint {hint:?} moved a main PSD: {x} vs {y}"));
                }
            }
            for (x, y) in warm.psd_fed.iter().zip(&cold.psd_fed) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("hint {hint:?} moved a fed PSD: {x} vs {y}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bcd_monotone_and_beats_baselines() {
    check("BCD monotone + dominance", 0xD00D, 12, |rng| {
        let scn = random_scenario(rng);
        let conv = ConvergenceModel::paper_default();
        let res = bcd::optimize(
            &scn,
            &conv,
            &BcdOptions {
                ranks: RANKS.to_vec(),
                ..BcdOptions::default()
            },
        )
        .map_err(|e| e.to_string())?;
        for w in res.trajectory.windows(2) {
            if w[1] > w[0] + 1e-9 {
                return Err(format!("objective rose: {:?}", res.trajectory));
            }
        }
        let mut brng = rng.fork(7);
        let (_, ta) =
            baselines::baseline_a(&scn, &conv, &RANKS, &mut brng).map_err(|e| e.to_string())?;
        if res.objective > ta * (1.0 + 1e-9) {
            return Err(format!("proposed {} worse than random {}", res.objective, ta));
        }
        Ok(())
    });
}

#[test]
fn prop_exhaustive_searches_are_argmin() {
    check("P3/P4 argmin", 0xE4E4, 20, |rng| {
        let scn = random_scenario(rng);
        let conv = ConvergenceModel::paper_default();
        let alloc = bcd::initial_alloc(&scn, 1 + rng.below(scn.profile.blocks.len() - 1), 4);
        let (l_star, t_star) = split::best_split(&scn, &alloc, &conv);
        for l_c in scn.profile.split_candidates() {
            let mut c = alloc.clone();
            c.l_c = l_c;
            if scn.total_delay(&c, &conv) < t_star - 1e-9 {
                return Err(format!("split {l_c} beats chosen {l_star}"));
            }
        }
        let (r_star, t_star) = rank::best_rank(&scn, &alloc, &conv, &RANKS);
        for &r in &RANKS {
            let mut c = alloc.clone();
            c.rank = r;
            if scn.total_delay(&c, &conv) < t_star - 1e-9 {
                return Err(format!("rank {r} beats chosen {r_star}"));
            }
        }
        Ok(())
    });
}
