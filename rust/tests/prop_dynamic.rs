//! Properties of the round-varying simulation engine (`sim::dynamic`):
//!
//! * **Static reduction** — a frozen environment (AR(1) correlation
//!   ρ = 1, or shadowing disabled) under the `OneShot` strategy must
//!   reproduce `Scenario::total_delay`'s static Eq. 17 prediction
//!   **bit for bit**, on every preset: the dynamic engine is a strict
//!   generalization of the static model, never a numerical change.
//! * **Re-optimization dominance** — under a drifting channel, at a
//!   fixed candidate rank, `EveryRound`'s realized delay is never
//!   worse than `OneShot`'s on any preset (the re-solve candidate set
//!   always contains the round-0 allocation and both runs visit the
//!   same round sequence), and strictly better somewhere.
//! * **Determinism** — same seeds give byte-identical trajectories and
//!   sweep reports at any thread count.
//! * **Energy accounting** — a frozen `OneShot` run's realized energy
//!   equals the static closed form `delay::energy::total_energy` bit
//!   for bit on every preset, and dropout rounds spend strictly less
//!   than full-cohort rounds of the same allocation.

use std::sync::Arc;

use sfllm::delay::energy::total_energy;
use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::policy::Proposed;
use sfllm::opt::{AllocationPolicy, PolicyRegistry};
use sfllm::sim::{
    DynamicPolicy, ReOptStrategy, RoundSimulator, ScenarioBuilder, SweepAxis, SweepRunner, PRESETS,
};

const RANKS: [usize; 2] = [1, 4];

/// Short E(r) so debug-mode runs stay cheap: E(1) = 8, E(4) ~ 5.2.
fn short_conv() -> ConvergenceModel {
    ConvergenceModel::fitted(4.0, 1.0, 0.85)
}

fn preset_builder(name: &str) -> ScenarioBuilder {
    ScenarioBuilder::preset(name)
        .unwrap()
        .tweak(|c| c.train.seq = 128)
}

#[test]
fn frozen_one_shot_reproduces_the_static_prediction_bit_for_bit_on_every_preset() {
    let conv = short_conv();
    for preset in PRESETS {
        let scn = preset_builder(preset)
            .channel_correlation(1.0)
            .tweak(|c| {
                c.dynamics.compute_jitter = 0.0;
                c.dynamics.dropout = 0.0;
            })
            .build()
            .unwrap();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let out = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
        let want = scn.total_delay(&out.final_alloc, &conv);
        assert_eq!(
            out.realized_delay.to_bits(),
            want.to_bits(),
            "{preset}: realized {} vs static {}",
            out.realized_delay,
            want
        );
        assert_eq!(
            out.static_prediction.to_bits(),
            want.to_bits(),
            "{preset}: static_prediction disagrees with Scenario::total_delay"
        );
        // every simulated round realized the identical delay
        let d0 = out.rounds[0].delay;
        assert!(out.rounds.iter().all(|r| r.delay.to_bits() == d0.to_bits()), "{preset}");
    }
}

#[test]
fn disabled_shadowing_process_reduces_to_the_static_scenario_bit_for_bit() {
    // with the scenario's shadowing at 0 the AR(1) process is frozen at
    // *any* correlation — including 0 — so the dynamic run degenerates
    // to the static scenario exactly
    for rho in [0.0, 0.5] {
        let scn = ScenarioBuilder::new()
            .clients(3)
            .channel_correlation(rho)
            .tweak(|c| {
                c.train.seq = 128;
                c.system.shadowing_db = 0.0;
            })
            .build()
            .unwrap();
        let conv = short_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let out = sim
            .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::OneShot)
            .unwrap();
        let want = scn.total_delay(&out.final_alloc, &conv);
        assert_eq!(
            out.realized_delay.to_bits(),
            want.to_bits(),
            "rho={rho}: zero-variance AR(1) must be the static scenario"
        );
    }
}

#[test]
fn frozen_one_shot_realized_energy_equals_the_static_closed_form_on_every_preset() {
    let conv = short_conv();
    for preset in PRESETS {
        let scn = preset_builder(preset)
            .channel_correlation(1.0)
            .tweak(|c| {
                c.dynamics.compute_jitter = 0.0;
                c.dynamics.dropout = 0.0;
            })
            .build()
            .unwrap();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let out = sim
            .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::OneShot)
            .unwrap();
        let want = total_energy(&scn, &out.final_alloc, &conv, scn.objective.zeta);
        assert_eq!(
            out.realized_energy.to_bits(),
            want.to_bits(),
            "{preset}: realized energy {} vs static {}",
            out.realized_energy,
            want
        );
        // every simulated round spent the identical energy
        let e0 = out.rounds[0].energy;
        assert!(e0.is_finite() && e0 > 0.0, "{preset}");
        assert!(
            out.rounds.iter().all(|r| r.energy.to_bits() == e0.to_bits()),
            "{preset}"
        );
    }
}

#[test]
fn dropout_rounds_spend_less_energy_than_full_cohort_rounds() {
    // freeze the channel and compute so the only round-to-round change
    // is membership: any round with a smaller active cohort must spend
    // strictly less than a full round of the same one-shot allocation
    let scn = ScenarioBuilder::new()
        .clients(4)
        .channel_correlation(1.0)
        .dropout(0.35, 0.5)
        .tweak(|c| {
            c.train.seq = 128;
            c.dynamics.seed = 5;
        })
        .build()
        .unwrap();
    let conv = ConvergenceModel::fitted(8.0, 1.0, 0.85);
    let cache = WorkloadCache::new();
    let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
    let out = sim
        .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::OneShot)
        .unwrap();
    let full: Vec<&sfllm::sim::RoundRecord> =
        out.rounds.iter().filter(|r| r.active == scn.k()).collect();
    let partial: Vec<&sfllm::sim::RoundRecord> =
        out.rounds.iter().filter(|r| r.active < scn.k()).collect();
    assert!(!full.is_empty() && !partial.is_empty(), "need both cohort sizes");
    let e_full = full[0].energy;
    for r in &partial {
        assert!(
            r.energy < e_full,
            "round {} ({} active) spent {} >= full-cohort {}",
            r.round,
            r.active,
            r.energy,
            e_full
        );
    }
    // realized total is the weighted trace sum
    let naive: f64 = out.rounds.iter().map(|r| r.weight * r.energy).sum();
    assert!((out.realized_energy - naive).abs() <= 1e-9 * naive);
}

#[test]
fn frozen_every_round_matches_one_shot_bit_for_bit() {
    // on a frozen channel every re-solve reproduces the round-0
    // solution; the tie-keep rule must hold the incumbent so the two
    // strategies realize identical totals
    let scn = ScenarioBuilder::new()
        .clients(3)
        .channel_correlation(1.0)
        .tweak(|c| c.train.seq = 128)
        .build()
        .unwrap();
    let conv = short_conv();
    let cache = WorkloadCache::new();
    let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
    let policy = Proposed::with_ranks(&RANKS);
    let one = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
    let every = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
    assert_eq!(one.realized_delay.to_bits(), every.realized_delay.to_bits());
    assert_eq!(one.rounds.len(), every.rounds.len());
    assert!(every.resolves > 0, "every_round must still have re-solved");
}

#[test]
fn every_round_never_worse_than_one_shot_on_every_preset_and_better_somewhere() {
    // At a fixed candidate rank this is a theorem, not an observation:
    // both strategies then visit the identical round/weight sequence,
    // and EveryRound's adoption set always contains the round-0
    // allocation, so its realized round delay dominates OneShot's
    // pointwise. (With rank switching the guarantee is per-round
    // cost-per-progress, not total ordering — a rank change re-times
    // the run against the channel trajectory.)
    let pinned: [usize; 1] = [4];
    let conv = short_conv();
    let mut strictly_better = 0usize;
    for preset in PRESETS {
        let scn = preset_builder(preset)
            .channel_correlation(0.8)
            .dynamics_seed(13)
            // pin the delay objective: the pointwise-dominance theorem
            // is per-objective, and battery_edge defaults to weighted
            .tweak(|c| c.objective = Default::default())
            .build()
            .unwrap();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &pinned);
        let policy = Proposed::with_ranks(&pinned);
        let one = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
        let every = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        assert_eq!(
            one.rounds.len(),
            every.rounds.len(),
            "{preset}: fixed rank must give identical round counts"
        );
        assert!(
            every.realized_delay <= one.realized_delay * (1.0 + 1e-12),
            "{preset}: every_round {} worse than one_shot {}",
            every.realized_delay,
            one.realized_delay
        );
        // pointwise dominance, the round-level form of the guarantee
        for (e, o) in every.rounds.iter().zip(&one.rounds) {
            assert!(
                e.delay <= o.delay * (1.0 + 1e-12),
                "{preset} round {}: re-opted delay {} worse than stale {}",
                e.round,
                e.delay,
                o.delay
            );
        }
        if every.realized_delay < one.realized_delay {
            strictly_better += 1;
        }
    }
    assert!(
        strictly_better > 0,
        "re-optimization never strictly beat one_shot on any preset — \
         the dynamic engine shows no gain"
    );
}

#[test]
fn trajectories_and_sweep_reports_are_deterministic_at_any_thread_count() {
    // direct simulator determinism, with every dynamics knob active
    let scn = ScenarioBuilder::preset("mobile_edge")
        .unwrap()
        .tweak(|c| c.train.seq = 128)
        .build()
        .unwrap();
    let conv = short_conv();
    let cache = WorkloadCache::new();
    let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
    let policy = Proposed::with_ranks(&RANKS);
    let a = sim.run(&policy, ReOptStrategy::Periodic(2)).unwrap();
    let b = sim.run(&policy, ReOptStrategy::Periodic(2)).unwrap();
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (x, y) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(x.delay.to_bits(), y.delay.to_bits(), "round {}", x.round);
        assert_eq!(x.weight.to_bits(), y.weight.to_bits(), "round {}", x.round);
        assert_eq!((x.active, x.rank, x.l_c, x.resolved), (y.active, y.rank, y.l_c, y.resolved));
    }

    // sweep-level determinism across worker thread counts
    let run = |threads: usize| {
        let base = ScenarioBuilder::new()
            .clients(3)
            .channel_correlation(0.7)
            .tweak(|c| c.train.seq = 128);
        let reg = PolicyRegistry::paper_suite(&RANKS, 7, 1);
        let inner = reg.get("proposed").unwrap();
        let policies: Vec<Arc<dyn AllocationPolicy>> = vec![
            Arc::new(DynamicPolicy::new(inner.clone(), ReOptStrategy::OneShot, &RANKS)),
            Arc::new(DynamicPolicy::new(inner, ReOptStrategy::EveryRound, &RANKS)),
        ];
        SweepRunner::new(&base)
            .over(SweepAxis::dropout(&[0.0, 0.15]))
            .policies(policies)
            .convergence(short_conv())
            .threads(threads)
            .run()
            .unwrap()
            .to_csv_string()
    };
    let single = run(1);
    let multi = run(3);
    assert_eq!(single, multi, "thread count changed the dynamic sweep bytes");
    assert_eq!(single.trim_end().lines().count(), 1 + 2);
}

#[test]
fn reopt_period_axis_drives_config_strategy_columns() {
    let base = ScenarioBuilder::new()
        .clients(3)
        .channel_correlation(0.7)
        .tweak(|c| c.train.seq = 128);
    let reg = PolicyRegistry::paper_suite(&RANKS, 7, 1);
    let inner = reg.get("proposed").unwrap();
    // one column deferring to the scenario's strategy, one pinned
    let policies: Vec<Arc<dyn AllocationPolicy>> = vec![
        Arc::new(DynamicPolicy::from_scenario(inner.clone(), &RANKS)),
        Arc::new(DynamicPolicy::new(inner, ReOptStrategy::Periodic(2), &RANKS)),
    ];
    let report = SweepRunner::new(&base)
        .over(SweepAxis::reopt_period(&[2.0, 4.0]))
        .policies(policies)
        .convergence(short_conv())
        .threads(1)
        .run()
        .unwrap();
    assert_eq!(report.policy_names, vec!["dyn:proposed", "proposed+periodic:2"]);
    assert_eq!(report.points.len(), 2);
    // at J = 2 the config-driven column must equal the pinned one
    let p0 = &report.points[0];
    assert_eq!(p0.coords, vec![2.0]);
    assert_eq!(
        p0.outcomes[0].objective.to_bits(),
        p0.outcomes[1].objective.to_bits(),
        "config-driven periodic:2 diverged from the explicit strategy"
    );
}

// ---------------------------------------------------------------------------
// PR-5: the delta re-optimization path (column cache + fresh-solve memo).

#[test]
fn frozen_runs_do_zero_solver_work_beyond_the_adoption_compare_on_every_preset() {
    // ρ = 1 freezes the channel: after round 0 the scenario handed to
    // the policy is bit-static, so a re-solve would reproduce the memo
    // exactly — the engine must serve it without running the solver
    // (fresh_solves == 0) under EVERY strategy, while still counting
    // the strategy's re-solve decisions and realizing the exact
    // OneShot totals.
    let conv = short_conv();
    for preset in PRESETS {
        let scn = preset_builder(preset)
            .channel_correlation(1.0)
            .tweak(|c| {
                c.dynamics.compute_jitter = 0.0;
                c.dynamics.dropout = 0.0;
            })
            .build()
            .unwrap();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let one = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
        assert_eq!(one.fresh_solves, 0, "{preset}: one_shot never re-solves");
        for strategy in [ReOptStrategy::EveryRound, ReOptStrategy::OnDegrade(0.0)] {
            let run = sim.run(&policy, strategy).unwrap();
            assert_eq!(
                run.fresh_solves, 0,
                "{preset}: frozen {} ran the solver",
                strategy.label()
            );
            assert_eq!(
                run.realized_delay.to_bits(),
                one.realized_delay.to_bits(),
                "{preset}: frozen {} moved the realized delay",
                strategy.label()
            );
            assert_eq!(
                run.realized_energy.to_bits(),
                one.realized_energy.to_bits(),
                "{preset}: frozen {} moved the realized energy",
                strategy.label()
            );
            for (a, b) in run.rounds.iter().zip(&one.rounds) {
                assert_eq!(a.delay.to_bits(), b.delay.to_bits(), "{preset}: round {}", a.round);
                assert_eq!(a.energy.to_bits(), b.energy.to_bits(), "{preset}: round {}", a.round);
                assert_eq!(a.weight.to_bits(), b.weight.to_bits(), "{preset}: round {}", a.round);
                assert_eq!((a.l_c, a.rank), (b.l_c, b.rank), "{preset}: round {}", a.round);
            }
        }
    }
}

#[test]
fn drifting_every_round_solves_fresh_every_round() {
    // the memo must never serve a stale solution once the channel moves
    let scn = preset_builder("mobile_edge")
        .channel_correlation(0.5)
        .build()
        .unwrap();
    let conv = short_conv();
    let cache = WorkloadCache::new();
    let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
    let run = sim
        .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::EveryRound)
        .unwrap();
    assert_eq!(run.fresh_solves, run.resolves, "drift must defeat the memo");
    assert!(run.fresh_solves > 0);
}

#[test]
fn frozen_dynamic_sweep_bytes_are_reproducible_and_strategy_invariant() {
    // the frozen-channel invariant at the sweep-report surface: the
    // every_round column (served entirely by the delta path: cached
    // rate columns + memoized solves) must carry the exact bytes of
    // the one_shot column, and repeated runs — whose ColumnCaches and
    // memos are freshly stateful each time — must reproduce the report
    // byte for byte.
    let builder = preset_builder("mobile_edge").channel_correlation(1.0).tweak(|c| {
        c.dynamics.compute_jitter = 0.0;
        c.dynamics.dropout = 0.0;
    });
    let conv = short_conv();
    let inner: Arc<dyn AllocationPolicy> = Arc::new(Proposed::with_ranks(&RANKS));
    let run_sweep = || {
        let policies: Vec<Arc<dyn AllocationPolicy>> = vec![
            Arc::new(DynamicPolicy::new(inner.clone(), ReOptStrategy::OneShot, &RANKS)),
            Arc::new(DynamicPolicy::new(inner.clone(), ReOptStrategy::EveryRound, &RANKS)),
        ];
        SweepRunner::new(&builder)
            .policies(policies)
            .convergence(conv.clone())
            .threads(1)
            .run()
            .unwrap()
    };
    let a = run_sweep();
    let b = run_sweep();
    assert_eq!(a.to_csv_string(), b.to_csv_string(), "sweep CSV bytes moved across runs");
    assert_eq!(a.to_json_string(), b.to_json_string(), "sweep JSON bytes moved across runs");
    let p = a.points.first().expect("one grid point");
    assert_eq!(
        p.outcomes[0].objective.to_bits(),
        p.outcomes[1].objective.to_bits(),
        "frozen every_round column diverged from one_shot"
    );
    assert_eq!(
        p.outcomes[0].delay.to_bits(),
        p.outcomes[1].delay.to_bits(),
    );
    assert_eq!(
        p.outcomes[0].energy.to_bits(),
        p.outcomes[1].energy.to_bits(),
    );
}
