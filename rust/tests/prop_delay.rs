//! Property tests over the Section-V delay model: monotonicities and
//! conservation laws that must hold for any random scenario.

use sfllm::config::Config;
use sfllm::delay::{Allocation, ConvergenceModel, Scenario};
use sfllm::opt::bcd::initial_alloc;
use sfllm::sim::ScenarioBuilder;
use sfllm::util::prop::check;
use sfllm::util::rng::Rng;

fn random_scenario(rng: &mut Rng) -> Scenario {
    let mut cfg = Config::paper_defaults();
    cfg.system.clients = 2 + rng.below(5);
    cfg.system.seed = rng.next_u64();
    cfg.train.batch = 1 + rng.below(32);
    cfg.train.seq = 128 << rng.below(3);
    ScenarioBuilder::from_config(cfg).build().expect("scenario")
}

fn some_alloc(scn: &Scenario, rng: &mut Rng) -> Allocation {
    let l_c = 1 + rng.below(scn.profile.blocks.len() - 1);
    initial_alloc(scn, l_c, *rng.choose(&[1usize, 2, 4, 6, 8]))
}

#[test]
fn prop_more_psd_never_slower() {
    check("PSD monotone", 1, 25, |rng| {
        let scn = random_scenario(rng);
        let a = some_alloc(&scn, rng);
        let mut hot = a.clone();
        let f = rng.range(1.1, 5.0);
        hot.psd_main.iter_mut().for_each(|p| *p *= f);
        hot.psd_fed.iter_mut().for_each(|p| *p *= f);
        let (p1, p2) = (scn.phase_delays(&a), scn.phase_delays(&hot));
        for k in 0..scn.k() {
            if p2.act_upload[k] > p1.act_upload[k] + 1e-12 {
                return Err(format!("upload slower with more power (client {k})"));
            }
            if p2.fed_upload[k] > p1.fed_upload[k] + 1e-12 {
                return Err(format!("fed upload slower with more power (client {k})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rank_increases_round_cost() {
    check("rank monotone in per-round cost", 2, 25, |rng| {
        let scn = random_scenario(rng);
        let a = some_alloc(&scn, rng);
        let mut lo = a.clone();
        lo.rank = 1;
        let mut hi = a.clone();
        hi.rank = 8;
        let (p1, p2) = (scn.phase_delays(&lo), scn.phase_delays(&hi));
        if p2.t_local() < p1.t_local() - 1e-12 {
            return Err("higher rank gave cheaper local round".into());
        }
        if p2.t_fed() < p1.t_fed() - 1e-12 {
            return Err("higher rank gave cheaper fed upload".into());
        }
        Ok(())
    });
}

#[test]
fn prop_compute_conservation_across_split() {
    check("split conserves total FLOPs", 3, 25, |rng| {
        let scn = random_scenario(rng);
        let r = *rng.choose(&[1usize, 2, 4, 6, 8]);
        let total = scn.profile.client_fwd_flops(scn.profile.blocks.len(), r);
        for l_c in 0..=scn.profile.blocks.len() {
            let c = scn.profile.client_fwd_flops(l_c, r);
            let s = scn.profile.server_fwd_flops(l_c, r) - scn.profile.head_fwd_flops;
            if ((c + s) - total).abs() > 1.0 {
                return Err(format!("split {l_c} lost FLOPs: {c}+{s} != {total}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_t_local_bounded_by_parts() {
    check("T_local composition bounds", 4, 25, |rng| {
        let scn = random_scenario(rng);
        let a = some_alloc(&scn, rng);
        let ph = scn.phase_delays(&a);
        let t = ph.t_local();
        // T_local is at least each stage and at most the sum of all stage maxima
        let s1 = ph
            .client_fwd
            .iter()
            .zip(&ph.act_upload)
            .map(|(x, y)| x + y)
            .fold(0.0f64, f64::max);
        let s3 = ph.client_bwd.iter().copied().fold(0.0f64, f64::max);
        let lo = s1.max(ph.server_fwd).max(ph.server_bwd).max(s3);
        let hi = s1 + ph.server_fwd + ph.server_bwd + s3;
        if t < lo - 1e-12 || t > hi + 1e-12 {
            return Err(format!("T_local {t} outside [{lo}, {hi}]"));
        }
        Ok(())
    });
}

#[test]
fn prop_total_delay_scales_with_rounds() {
    check("E(r) scaling", 5, 15, |rng| {
        let scn = random_scenario(rng);
        let a = some_alloc(&scn, rng);
        let e1 = ConvergenceModel::fitted(10.0, 0.0, 1.0); // constant 10 rounds
        let e2 = ConvergenceModel::fitted(20.0, 0.0, 1.0); // constant 20 rounds
        let t1 = scn.total_delay(&a, &e1);
        let t2 = scn.total_delay(&a, &e2);
        if (t2 - 2.0 * t1).abs() / t1.max(1e-12) > 1e-9 {
            return Err(format!("doubling E(r) must double T: {t1} vs {t2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_slower_client_never_reduces_t_local() {
    check("straggler monotone", 6, 20, |rng| {
        let mut scn = random_scenario(rng);
        let a = some_alloc(&scn, rng);
        let t_before = scn.t_local(&a);
        let victim = rng.below(scn.k());
        scn.topo.clients[victim].f_cycles /= rng.range(1.5, 10.0);
        let t_after = scn.t_local(&a);
        if t_after < t_before - 1e-12 {
            return Err("slowing a client reduced T_local".into());
        }
        Ok(())
    });
}
