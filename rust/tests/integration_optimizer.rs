//! Integration: the full Section-VI pipeline on the paper's Table-II
//! scenario — Algorithm 3 end-to-end, baseline dominance, and the
//! qualitative trends Figs. 5–8 rely on.

use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::baselines;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::sim::build_scenario;

fn paper_scenario() -> sfllm::delay::Scenario {
    build_scenario(&Config::paper_defaults()).unwrap()
}

fn opts() -> BcdOptions {
    BcdOptions::default()
}

#[test]
fn bcd_on_paper_scenario_converges() {
    let scn = paper_scenario();
    let conv = ConvergenceModel::paper_default();
    let res = bcd::optimize(&scn, &conv, &opts()).unwrap();
    assert!(res.objective.is_finite() && res.objective > 0.0);
    assert!(res.iterations <= 20);
    res.alloc
        .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
        .unwrap();
    assert!(scn.power_feasible(&res.alloc, 1e-6));
}

#[test]
fn proposed_dominates_all_baselines_on_paper_scenario() {
    let scn = paper_scenario();
    let conv = ConvergenceModel::paper_default();
    let [p, a, b, c, d] =
        baselines::compare_all(&scn, &conv, &[1, 2, 4, 6, 8], 42, 5).unwrap();
    assert!(p <= a && p <= b && p <= c && p <= d, "p={p} a={a} b={b} c={c} d={d}");
    // paper claims up to ~60% reduction vs baseline a at Table II defaults
    let reduction = 1.0 - p / a;
    assert!(
        reduction > 0.25,
        "expected a substantial reduction vs random, got {:.0}%",
        reduction * 100.0
    );
}

#[test]
fn fig5_trend_latency_decreases_with_bandwidth() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    for bw in [250e3, 500e3, 1000e3] {
        let mut cfg = Config::paper_defaults();
        cfg.system.bandwidth_main_hz = bw;
        cfg.system.bandwidth_fed_hz = bw;
        let scn = build_scenario(&cfg).unwrap();
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t < last, "bandwidth {bw}: {t} !< {last}");
        last = t;
    }
}

#[test]
fn fig6_trend_latency_decreases_with_client_compute() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    // sweep client FLOPs-per-cycle via kappa (lower kappa = stronger client)
    for kappa_inv in [512.0, 1024.0, 4096.0] {
        let mut cfg = Config::paper_defaults();
        cfg.system.kappa_client = 1.0 / kappa_inv;
        let scn = build_scenario(&cfg).unwrap();
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t < last, "kappa 1/{kappa_inv}: {t} !< {last}");
        last = t;
    }
}

#[test]
fn fig7_trend_latency_decreases_with_server_compute() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    for f_s in [2.5e9, 5e9, 20e9] {
        let mut cfg = Config::paper_defaults();
        cfg.system.f_server = f_s;
        let scn = build_scenario(&cfg).unwrap();
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t <= last, "f_s {f_s}: {t} !<= {last}");
        last = t;
    }
}

#[test]
fn fig8_trend_latency_decreases_with_transmit_power() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    for p_dbm in [31.76, 41.76, 47.0] {
        let mut cfg = Config::paper_defaults();
        cfg.system.p_max_dbm = p_dbm;
        let scn = build_scenario(&cfg).unwrap();
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t <= last, "p_max {p_dbm} dBm: {t} !<= {last}");
        last = t;
    }
}

#[test]
fn weak_clients_shift_split_toward_server() {
    let conv = ConvergenceModel::paper_default();
    let mut strong = Config::paper_defaults();
    strong.system.kappa_client = 1.0 / 16384.0; // very strong clients
    let mut weak = Config::paper_defaults();
    weak.system.kappa_client = 1.0 / 128.0; // very weak clients
    let l_strong = bcd::optimize(&build_scenario(&strong).unwrap(), &conv, &opts())
        .unwrap()
        .alloc
        .l_c;
    let l_weak = bcd::optimize(&build_scenario(&weak).unwrap(), &conv, &opts())
        .unwrap()
        .alloc
        .l_c;
    assert!(l_weak <= l_strong, "weak {l_weak} vs strong {l_strong}");
}
