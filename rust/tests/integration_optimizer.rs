//! Integration: the full Section-VI pipeline on the paper's Table-II
//! scenario — Algorithm 3 end-to-end, baseline dominance, and the
//! qualitative trends Figs. 5–8 rely on.

use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::opt::PolicyRegistry;
use sfllm::sim::ScenarioBuilder;

fn paper_scenario() -> sfllm::delay::Scenario {
    ScenarioBuilder::preset("paper").unwrap().build().unwrap()
}

fn scenario_from(cfg: Config) -> sfllm::delay::Scenario {
    ScenarioBuilder::from_config(cfg).build().unwrap()
}

fn opts() -> BcdOptions {
    BcdOptions::default()
}

#[test]
fn bcd_on_paper_scenario_converges() {
    let scn = paper_scenario();
    let conv = ConvergenceModel::paper_default();
    let res = bcd::optimize(&scn, &conv, &opts()).unwrap();
    assert!(res.objective.is_finite() && res.objective > 0.0);
    assert!(res.iterations <= 20);
    res.alloc
        .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
        .unwrap();
    assert!(scn.power_feasible(&res.alloc, 1e-6));
}

#[test]
fn proposed_dominates_all_baselines_on_paper_scenario() {
    // the paper's Sec. VII-C comparison through the policy registry
    let scn = paper_scenario();
    let conv = ConvergenceModel::paper_default();
    let reg = PolicyRegistry::paper_suite(&[1, 2, 4, 6, 8], 42, 5);
    let mut objectives = std::collections::BTreeMap::new();
    for policy in reg.resolve("all").unwrap() {
        let out = policy.solve(&scn, &conv).unwrap();
        assert!(out.objective.is_finite() && out.objective > 0.0, "{}", out.policy);
        out.alloc
            .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
            .unwrap_or_else(|e| panic!("{}: {e}", out.policy));
        assert!(scn.power_feasible(&out.alloc, 1e-6), "{}", out.policy);
        objectives.insert(out.policy, out.objective);
    }
    let p = objectives["proposed"];
    for (name, &t) in &objectives {
        assert!(p <= t * (1.0 + 1e-9), "proposed {p} must beat {name} {t}");
    }
    // paper claims up to ~60% reduction vs baseline a at Table II defaults
    let a = objectives["baseline_a"];
    assert!(1.0 - p / a > 0.25, "reduction vs random too small: p={p} a={a}");
}

#[test]
fn fig5_trend_latency_decreases_with_bandwidth() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    for bw in [250e3, 500e3, 1000e3] {
        let mut cfg = Config::paper_defaults();
        cfg.system.bandwidth_main_hz = bw;
        cfg.system.bandwidth_fed_hz = bw;
        let scn = scenario_from(cfg);
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t < last, "bandwidth {bw}: {t} !< {last}");
        last = t;
    }
}

#[test]
fn fig6_trend_latency_decreases_with_client_compute() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    // sweep client FLOPs-per-cycle via kappa (lower kappa = stronger client)
    for kappa_inv in [512.0, 1024.0, 4096.0] {
        let mut cfg = Config::paper_defaults();
        cfg.system.kappa_client = 1.0 / kappa_inv;
        let scn = scenario_from(cfg);
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t < last, "kappa 1/{kappa_inv}: {t} !< {last}");
        last = t;
    }
}

#[test]
fn fig7_trend_latency_decreases_with_server_compute() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    for f_s in [2.5e9, 5e9, 20e9] {
        let mut cfg = Config::paper_defaults();
        cfg.system.f_server = f_s;
        let scn = scenario_from(cfg);
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t <= last, "f_s {f_s}: {t} !<= {last}");
        last = t;
    }
}

#[test]
fn fig8_trend_latency_decreases_with_transmit_power() {
    let conv = ConvergenceModel::paper_default();
    let mut last = f64::INFINITY;
    for p_dbm in [31.76, 41.76, 47.0] {
        let mut cfg = Config::paper_defaults();
        cfg.system.p_max_dbm = p_dbm;
        let scn = scenario_from(cfg);
        let t = bcd::optimize(&scn, &conv, &opts()).unwrap().objective;
        assert!(t <= last, "p_max {p_dbm} dBm: {t} !<= {last}");
        last = t;
    }
}

#[test]
fn weak_clients_shift_split_toward_server() {
    let conv = ConvergenceModel::paper_default();
    let mut strong = Config::paper_defaults();
    strong.system.kappa_client = 1.0 / 16384.0; // very strong clients
    let mut weak = Config::paper_defaults();
    weak.system.kappa_client = 1.0 / 128.0; // very weak clients
    let l_strong = bcd::optimize(&scenario_from(strong), &conv, &opts())
        .unwrap()
        .alloc
        .l_c;
    let l_weak = bcd::optimize(&scenario_from(weak), &conv, &opts())
        .unwrap()
        .alloc
        .l_c;
    assert!(l_weak <= l_strong, "weak {l_weak} vs strong {l_strong}");
}
