//! Properties of the incremental (heap-based) Algorithm 2 engine
//! (`opt::assignment`):
//!
//! * **Bit-identity** — `algorithm2` (cached rate/power accumulators +
//!   lazy straggler max-heap) must produce the *exact* grants of the
//!   naive `algorithm2_reference` scan — same subchannels, same
//!   clients, same per-client order — on every builder preset and on
//!   seeded random scenarios whose power budgets are squeezed until the
//!   C4/C5 caps genuinely bind (the only regime where the two engines'
//!   control flow actually diverges from the trivial path).
//! * **Scratch transparency** — reusing one [`AssignScratch`] across
//!   calls (the BCD loop's hoisted per-link sort orders) never changes
//!   a grant versus fresh single-use calls.
//! * **Per-subchannel eligibility (bugfix)** — a client barred by C4
//!   from a wide subchannel is re-tested on later, narrower ones: the
//!   old implementation latched the exclusion for the rest of the
//!   pass, permanently starving the straggler it was built to serve.

use sfllm::config::Config;
use sfllm::delay::Scenario;
use sfllm::model::{Gpt2Config, WorkloadProfile};
use sfllm::net::topology::ClientSite;
use sfllm::net::{Link, SubchannelSet, Topology};
use sfllm::opt::assignment::{
    algorithm2, algorithm2_reference, algorithm2_with, AssignScratch,
};
use sfllm::sim::{ScenarioBuilder, PRESETS};
use sfllm::util::prop::check;

const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

fn assert_identical(scn: &Scenario, l_c: usize, rank: usize, tag: &str) -> Result<(), String> {
    let fast = algorithm2(scn, l_c, rank);
    let refr = algorithm2_reference(scn, l_c, rank);
    if fast.assign_main != refr.assign_main {
        return Err(format!(
            "{tag}: main grants diverge at l_c={l_c} r={rank}: {:?} vs {:?}",
            fast.assign_main, refr.assign_main
        ));
    }
    if fast.assign_fed != refr.assign_fed {
        return Err(format!(
            "{tag}: fed grants diverge at l_c={l_c} r={rank}: {:?} vs {:?}",
            fast.assign_fed, refr.assign_fed
        ));
    }
    if fast.psd_main_nominal.to_bits() != refr.psd_main_nominal.to_bits()
        || fast.psd_fed_nominal.to_bits() != refr.psd_fed_nominal.to_bits()
    {
        return Err(format!("{tag}: nominal PSDs diverge"));
    }
    Ok(())
}

#[test]
fn heap_engine_is_bit_identical_to_the_reference_on_every_preset() {
    for preset in PRESETS {
        let scn = ScenarioBuilder::preset(preset)
            .unwrap()
            .tweak(|c| c.train.seq = 128)
            .build()
            .unwrap();
        let l_mid = (scn.profile.blocks.len() / 2).max(1);
        for (l_c, r) in [(l_mid, 4), (1, 1), (scn.profile.blocks.len() - 1, 8)] {
            assert_identical(&scn, l_c, r, preset).unwrap_or_else(|e| panic!("{e}"));
        }
    }
}

#[test]
fn heap_engine_is_bit_identical_on_seeded_random_scenarios() {
    check("algorithm2 heap == reference", 0x5EED, 40, |rng| {
        let mut cfg = Config::paper_defaults();
        cfg.system.clients = 2 + rng.below(9); // 2..=10
        cfg.system.subch_main = cfg.system.clients + rng.below(40);
        cfg.system.subch_fed = cfg.system.clients + rng.below(40);
        cfg.system.bandwidth_main_hz = rng.range(100e3, 4e6);
        cfg.system.bandwidth_fed_hz = rng.range(100e3, 4e6);
        cfg.system.d_main_m = rng.range(50.0, 300.0);
        cfg.system.seed = rng.next_u64();
        // squeeze the power caps so C4/C5 genuinely bind: this is the
        // regime where the straggler heap, the deferred retests, and
        // the round-robin fallback all fire
        cfg.system.p_max_dbm = rng.range(30.0, 42.0);
        cfg.system.p_th_main_dbm = rng.range(38.0, 47.0);
        cfg.system.p_th_fed_dbm = rng.range(38.0, 47.0);
        cfg.train.batch = 1 + rng.below(32);
        cfg.train.seq = 128 << rng.below(2);
        let scn = ScenarioBuilder::from_config(cfg).build().expect("scenario build");
        let l_c = 1 + rng.below(scn.profile.blocks.len() - 1);
        let r = *rng.choose(&RANKS);
        assert_identical(&scn, l_c, r, "random")
    });
}

#[test]
fn scratch_reuse_never_changes_a_grant() {
    check("AssignScratch transparency", 0x5C8A, 15, |rng| {
        let mut cfg = Config::paper_defaults();
        cfg.system.clients = 2 + rng.below(6);
        cfg.system.subch_main = cfg.system.clients + rng.below(20);
        cfg.system.subch_fed = cfg.system.clients + rng.below(20);
        cfg.system.seed = rng.next_u64();
        cfg.train.seq = 128;
        let scn = ScenarioBuilder::from_config(cfg).build().expect("scenario build");
        let mut scratch = AssignScratch::new();
        for _ in 0..4 {
            let l_c = 1 + rng.below(scn.profile.blocks.len() - 1);
            let r = *rng.choose(&RANKS);
            let with = algorithm2_with(&scn, l_c, r, &mut scratch);
            let fresh = algorithm2(&scn, l_c, r);
            if with.assign_main != fresh.assign_main || with.assign_fed != fresh.assign_fed {
                return Err(format!("scratch reuse diverged at l_c={l_c} r={r}"));
            }
        }
        Ok(())
    });
}

/// Handcrafted scenario reproducing the eligibility-latch bug: the
/// straggler (client 0: 0.01 GHz — orders of magnitude slower than
/// client 1) is barred by C4 from a wide phase-2 subchannel, and a
/// narrower (cheaper) subchannel later in the pass *does* fit its cap.
/// The old `eligible[k] = false` latch dropped client 0 for the rest of
/// the pass, handing the narrow subchannel to the fast client; the
/// per-subchannel retest gives it to the straggler.
fn latch_trap_scenario() -> Scenario {
    let topo = Topology {
        clients: vec![
            ClientSite { d_main_m: 100.0, d_fed_m: 10.0, f_cycles: 0.01e9 },
            ClientSite { d_main_m: 100.0, d_fed_m: 10.0, f_cycles: 5.0e9 },
        ],
    };
    // widest-first order: ids [0 (300k), 2 (150k), 1 (100k), 3 (50k), 4 (49k)]
    // nominal PSD = 64.9 W / 649 kHz = 1e-4 W/Hz
    // -> per-subchannel powers ~ [30, 10, 15, 5, 4.9] W
    let main_link = Link {
        subch: SubchannelSet { bandwidth_hz: vec![300e3, 100e3, 150e3, 50e3, 49e3] },
        gain_product: 160.0,
        noise_psd: 3.98e-21,
        client_gain: vec![8.9e-10, 8.9e-10],
    };
    let fed_link = Link {
        subch: SubchannelSet::equal_split(500e3, 2),
        gain_product: 80.0,
        noise_psd: 3.98e-21,
        client_gain: vec![1.2e-9, 1.2e-9],
    };
    Scenario {
        profile: WorkloadProfile::new(Gpt2Config::gpt2_s(), 128),
        topo,
        main_link,
        fed_link,
        dynamics: sfllm::config::DynamicsConfig::default(),
        objective: sfllm::config::ObjectiveConfig::default(),
        kappa_client: 1.0 / 1024.0,
        kappa_server: 1.0 / 32768.0,
        f_server: 5e9,
        batch: 4,
        local_steps: 3,
        // phase 1 parks client 0 at 30 W and client 1 at 15 W. The
        // 100 kHz subchannel (+10 W) busts client 0's 38 W cap (40 W)
        // but fits client 1; the 50 kHz one (+5 W -> 35 W) fits the
        // straggler again.
        p_max_w: 38.0,
        p_th_main_w: 64.9,
        p_th_fed_w: 50.0,
    }
}

#[test]
fn client_barred_from_a_wide_subchannel_still_gets_a_narrower_one() {
    let scn = latch_trap_scenario();
    let fast = algorithm2(&scn, 3, 4);
    let refr = algorithm2_reference(&scn, 3, 4);
    assert_eq!(fast.assign_main, refr.assign_main, "engines diverge");
    assert_eq!(fast.assign_fed, refr.assign_fed, "engines diverge");
    // phase 1: client 0 (weakest) takes id 0, client 1 takes id 2
    assert_eq!(fast.assign_main[0][0], 0);
    assert_eq!(fast.assign_main[1][0], 2);
    // the wide 100 kHz subchannel (id 1) busts the straggler's cap and
    // lands on client 1 ...
    assert!(
        fast.assign_main[1].contains(&1),
        "wide subchannel should fall to the fast client: {:?}",
        fast.assign_main
    );
    // ... and the narrow 50 kHz one (id 3) must come back to the
    // straggler — the latched implementation gave it to client 1
    assert!(
        fast.assign_main[0].contains(&3),
        "straggler lost the narrow subchannel it can afford: {:?}",
        fast.assign_main
    );
}
