//! Self-tests for `sfllm-lint` (PR-7 lexical engine, PR-9 structural
//! engine).
//!
//! Three layers:
//!
//! 1. **Lexical fixture corpus** (`tests/lint_fixtures/`): one firing
//!    and one clean fixture per *lexical* rule ID, embedded with
//!    `include_str!` and fed through
//!    [`sfllm::analysis::check_source`] under a synthetic
//!    repo-relative path. A firing fixture must produce findings for
//!    exactly its rule; a clean fixture must produce none.
//! 2. **Program fixtures**: the *program* rules (P101/D104 taint,
//!    G001/G002 layering, A002 hygiene) need several files at once, so
//!    they are exercised through [`sfllm::analysis::lint_sources`]
//!    with small in-memory trees — including the acceptance case that
//!    the old lexical hot-scope rules could not see: a panic in a
//!    `util/` helper reached from an `opt/` entry point.
//! 3. **Repo-wide gate**: the real tree walk must come back with zero
//!    unsuppressed findings and a byte-stable `ARCH.json` — the same
//!    invariants the CI `lint` job and `sfllm lint` enforce.

use sfllm::analysis::graph::{layer_fingerprint, ALLOWED, LAYERS};
use sfllm::analysis::parse::parse_file;
use sfllm::analysis::{
    check_source, lint_repo, lint_sources, rule_ids, LintOptions, SourceFile,
};

/// Synthetic path for rules that apply to all non-test library code.
const SRC_REL: &str = "rust/src/fake/mod.rs";
/// Synthetic path inside the hot scope (`opt/`, `delay/`, `sim/`).
const HOT_REL: &str = "rust/src/opt/fixture.rs";

/// Rules checked per-file over the token stream (fixture pairs below).
const LEXICAL_RULES: &[&str] = &["D001", "D002", "D003", "D005", "N001", "N002", "A001"];
/// Rules that need the whole parsed tree (program tests below).
const PROGRAM_RULES: &[&str] = &["D104", "P101", "G001", "G002", "A002"];

struct Case {
    rule: &'static str,
    rel: &'static str,
    fire: &'static str,
    clean: &'static str,
    /// Finding count expected from the firing fixture.
    expected: usize,
}

const CASES: &[Case] = &[
    Case {
        rule: "D001",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d001_fire.rs"),
        clean: include_str!("lint_fixtures/d001_clean.rs"),
        expected: 3,
    },
    Case {
        rule: "D002",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d002_fire.rs"),
        clean: include_str!("lint_fixtures/d002_clean.rs"),
        expected: 1,
    },
    Case {
        rule: "D003",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d003_fire.rs"),
        clean: include_str!("lint_fixtures/d003_clean.rs"),
        expected: 2,
    },
    Case {
        rule: "D005",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d005_fire.rs"),
        clean: include_str!("lint_fixtures/d005_clean.rs"),
        expected: 3,
    },
    Case {
        rule: "N001",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/n001_fire.rs"),
        clean: include_str!("lint_fixtures/n001_clean.rs"),
        expected: 1,
    },
    Case {
        rule: "N002",
        rel: HOT_REL,
        fire: include_str!("lint_fixtures/n002_fire.rs"),
        clean: include_str!("lint_fixtures/n002_clean.rs"),
        expected: 2,
    },
    Case {
        rule: "A001",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/a001_fire.rs"),
        clean: include_str!("lint_fixtures/a001_clean.rs"),
        expected: 2,
    },
];

/// Builds the in-memory tree for a program-rule test.
fn tree(files: &[(&str, &str)]) -> Vec<SourceFile> {
    files
        .iter()
        .map(|(rel, src)| SourceFile {
            rel: rel.to_string(),
            src: src.to_string(),
        })
        .collect()
}

#[test]
fn every_rule_is_covered_and_classified() {
    let covered: Vec<&str> = CASES
        .iter()
        .map(|c| c.rule)
        .chain(PROGRAM_RULES.iter().copied())
        .collect();
    for id in rule_ids() {
        let n = covered.iter().filter(|&&r| r == id).count();
        assert_eq!(n, 1, "rule {id} needs exactly one fixture/program case");
    }
    assert_eq!(covered.len(), rule_ids().len());
    for c in CASES {
        assert!(LEXICAL_RULES.contains(&c.rule), "{} misclassified", c.rule);
    }
    // the retired lexical IDs must be gone: a stale allow naming them
    // has to fail as A001, which only works if they left the catalogue
    for retired in ["P001", "P002", "D004"] {
        assert!(!rule_ids().contains(&retired), "{retired} still in catalogue");
    }
}

#[test]
fn firing_fixtures_fire_exactly_their_rule() {
    for c in CASES {
        let (findings, _) = check_source(c.rel, c.fire);
        assert_eq!(findings.len(), c.expected, "{}: got {findings:?}", c.rule);
        for f in &findings {
            assert_eq!(f.rule, c.rule, "{}: stray finding {f:?}", c.rule);
            assert_eq!(f.file, c.rel);
            assert!(f.line > 0);
            assert!(!f.snippet.is_empty());
            assert!(!f.message.is_empty());
        }
    }
}

#[test]
fn clean_fixtures_are_clean() {
    for c in CASES {
        let (findings, _) = check_source(c.rel, c.clean);
        assert!(findings.is_empty(), "{} clean fixture fired: {findings:?}", c.rule);
    }
}

#[test]
fn clean_suppressions_are_marked_used() {
    let a001_clean = include_str!("lint_fixtures/a001_clean.rs");
    let (findings, sups) = check_source(SRC_REL, a001_clean);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(sups.len(), 2);
    for s in &sups {
        assert!(s.used, "suppression at line {} should be used", s.line);
        assert_eq!(s.rules, ["D001"]);
    }
}

#[test]
fn suppression_covers_its_own_line() {
    let src = "use std::collections::HashMap; // lint:allow(D001) membership probe only here\n";
    let (findings, sups) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(sups.len(), 1);
    assert!(sups[0].used);
}

#[test]
fn standalone_suppression_covers_the_next_code_line() {
    let src = "// lint:allow(D001) membership probe only here\n\
               use std::collections::HashMap;\n";
    let (findings, sups) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
    assert!(sups[0].used);
}

#[test]
fn suppression_does_not_reach_two_lines_down() {
    let src = "// lint:allow(D001) membership probe only here\n\
               fn pad() {}\n\
               use std::collections::HashMap;\n";
    let (findings, sups) = check_source(SRC_REL, src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "D001");
    assert_eq!(findings[0].line, 3);
    assert!(!sups[0].used, "suppression two lines up must not apply");
}

#[test]
fn empty_rule_list_is_a001() {
    let src = "// lint:allow() forgot to name the rule being suppressed\nfn f() {}\n";
    let (findings, _) = check_source(SRC_REL, src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "A001");
}

#[test]
fn stale_allow_naming_a_retired_rule_is_a001() {
    // PR-9 retired P001/P002/D004; an allow still naming them must not
    // silently rot — it names an unknown rule, which is A001.
    let src = "// lint:allow(P001) leftover from the lexical hot-scope era\nfn f() {}\n";
    let (findings, _) = check_source(HOT_REL, src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "A001");
}

#[test]
fn strings_and_comments_never_trigger_rules() {
    let src = "// prose mentioning HashMap and Instant::now is fine\n\
               pub fn banner() -> &'static str {\n\
                   \"HashMap thread_rng Instant::now partial_cmp\"\n\
               }\n";
    let (findings, _) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn partial_cmp_definitions_are_exempt() {
    // Implementing PartialOrd *defines* partial_cmp; only call sites
    // are NaN hazards.
    let src = "struct W(u64);\n\
               impl PartialOrd for W {\n\
                   fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {\n\
                       Some(self.0.cmp(&other.0))\n\
                   }\n\
               }\n";
    let (findings, _) = check_source(HOT_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn cfg_test_blocks_are_exempt_from_lib_rules() {
    let src = "#[cfg(test)]\n\
               mod tests {\n\
                   use std::collections::HashMap;\n\
                   #[test]\n\
                   fn t() {\n\
                       let m: HashMap<u32, u32> = HashMap::new();\n\
                       assert!(m.is_empty());\n\
                   }\n\
               }\n";
    let (findings, _) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
}

// ---------------------------------------------------------------------
// Program rules: interprocedural taint (P101/D104)
// ---------------------------------------------------------------------

/// The PR-9 acceptance case: an `opt/` entry point calls a `util/`
/// helper whose body unwraps. The lexical hot-scope rule (retired
/// P001) only looked at files under `opt/`/`delay/`/`sim/`, so the
/// panic was invisible; the taint pass follows the call edge.
#[test]
fn cross_module_panic_chain_is_caught_and_lexical_scoping_missed_it() {
    let entry = "use crate::util::pick::pick;\n\
                 pub fn solve(xs: &[f64]) -> f64 {\n    pick(xs)\n}\n";
    let helper = "pub fn pick(xs: &[f64]) -> f64 {\n    *xs.first().unwrap()\n}\n";

    // the old per-file view: neither file shows anything — the hot
    // file has no panic site, and util/ was outside the lexical scope
    let (entry_lex, _) = check_source("rust/src/opt/entry.rs", entry);
    let (helper_lex, _) = check_source("rust/src/util/pick.rs", helper);
    assert!(entry_lex.is_empty(), "{entry_lex:?}");
    assert!(helper_lex.is_empty(), "{helper_lex:?}");

    // the whole-program view: P101 lands on the helper's unwrap with
    // the full call chain from the hot entry in the message
    let report = lint_sources(
        &tree(&[
            ("rust/src/opt/entry.rs", entry),
            ("rust/src/util/pick.rs", helper),
        ]),
        &LintOptions::default(),
    );
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    let f = &report.findings[0];
    assert_eq!(f.rule, "P101");
    assert_eq!(f.file, "rust/src/util/pick.rs");
    assert_eq!(f.line, 2);
    assert_eq!(f.snippet, ".unwrap()");
    assert!(
        f.message.contains("opt::entry::solve -> util::pick::pick"),
        "chain missing from message: {}",
        f.message
    );
}

#[test]
fn unreachable_panic_sites_stay_silent() {
    // same helper, but nothing in the hot scope calls it
    let report = lint_sources(
        &tree(&[
            ("rust/src/opt/entry.rs", "pub fn solve() -> f64 { 1.0 }\n"),
            (
                "rust/src/util/pick.rs",
                "pub fn pick(xs: &[f64]) -> f64 { *xs.first().unwrap() }\n",
            ),
        ]),
        &LintOptions::default(),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn d104_flags_reductions_reachable_from_spawn_sites() {
    let spawner = "use crate::util::acc::acc;\n\
                   fn worker(xs: &[f64]) -> f64 {\n    acc(xs)\n}\n\
                   pub fn fan_out(xs: &[f64]) -> f64 {\n\
                       std::thread::scope(|s| {\n        s.spawn(|| worker(xs));\n    });\n\
                       0.0\n}\n";
    let helper = "pub fn acc(xs: &[f64]) -> f64 {\n    xs.iter().sum()\n}\n";
    let report = lint_sources(
        &tree(&[
            ("rust/src/coordinator/fan.rs", spawner),
            ("rust/src/util/acc.rs", helper),
        ]),
        &LintOptions::default(),
    );
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    let f = &report.findings[0];
    assert_eq!(f.rule, "D104");
    assert_eq!(f.file, "rust/src/util/acc.rs");
    assert_eq!(f.snippet, ".sum()");
    assert!(
        f.message.contains("coordinator::fan::fan_out"),
        "chain missing: {}",
        f.message
    );
}

#[test]
fn program_findings_honor_inline_suppressions() {
    let entry = "use crate::util::pick::pick;\n\
                 pub fn solve(xs: &[f64]) -> f64 {\n    pick(xs)\n}\n";
    let helper = "pub fn pick(xs: &[f64]) -> f64 {\n    // lint:allow(P101) caller validates xs non-empty upstream\n    *xs.first().unwrap()\n}\n";
    let report = lint_sources(
        &tree(&[
            ("rust/src/opt/entry.rs", entry),
            ("rust/src/util/pick.rs", helper),
        ]),
        &LintOptions::default(),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
    let sup = report
        .suppressions
        .iter()
        .find(|s| s.file == "rust/src/util/pick.rs")
        .expect("suppression collected");
    assert!(sup.used, "P101 suppression must be marked used (no A002)");
}

// ---------------------------------------------------------------------
// Program rules: module graph (G001/G002)
// ---------------------------------------------------------------------

#[test]
fn layering_inversion_is_exactly_one_g002() {
    // util (layer 0) reaching up into opt (layer 3): one edge, one G002
    let report = lint_sources(
        &tree(&[
            (
                "rust/src/util/bad.rs",
                "pub fn f() -> f64 { crate::opt::run() }\n",
            ),
            ("rust/src/opt/entry.rs", "pub fn run() -> f64 { 1.0 }\n"),
        ]),
        &LintOptions::default(),
    );
    let g002: Vec<_> = report.findings.iter().filter(|f| f.rule == "G002").collect();
    assert_eq!(g002.len(), 1, "{:?}", report.findings);
    assert_eq!(g002[0].file, "rust/src/util/bad.rs");
    assert_eq!(g002[0].snippet, "util -> opt");
    assert!(g002[0].message.contains("layer"), "{}", g002[0].message);
    assert_eq!(report.arch.count("G002"), 1);
    assert_eq!(report.arch.count("G001"), 0);
}

#[test]
fn dependency_cycle_is_exactly_one_g001() {
    // opt -> delay is allowed; delay -> opt closes a cycle (and is
    // itself an inversion): exactly one G001 and one G002.
    let report = lint_sources(
        &tree(&[
            (
                "rust/src/opt/a.rs",
                "pub fn f() -> f64 { crate::delay::g() }\n",
            ),
            (
                "rust/src/delay/b.rs",
                "pub fn g() -> f64 { crate::opt::f() }\n",
            ),
        ]),
        &LintOptions::default(),
    );
    assert_eq!(report.arch.count("G001"), 1, "{:?}", report.findings);
    assert_eq!(report.arch.count("G002"), 1, "{:?}", report.findings);
    let g001 = report.findings.iter().find(|f| f.rule == "G001").expect("G001 reported");
    assert!(g001.message.contains("cycle"), "{}", g001.message);
}

#[test]
fn allowed_edges_produce_no_graph_findings() {
    let report = lint_sources(
        &tree(&[
            (
                "rust/src/opt/a.rs",
                "pub fn f() -> f64 { crate::delay::g() + crate::util::h() }\n",
            ),
            ("rust/src/delay/b.rs", "pub fn g() -> f64 { crate::util::h() }\n"),
            ("rust/src/util/c.rs", "pub fn h() -> f64 { 1.0 }\n"),
        ]),
        &LintOptions::default(),
    );
    assert!(report.findings.is_empty(), "{:?}", report.findings);
    assert_eq!(report.arch.edges.len(), 3);
    assert!(report.arch.edges.iter().all(|e| e.allowed));
}

#[test]
fn layer_table_is_strictly_decreasing_and_fingerprinted() {
    // every allowed edge must point at a strictly lower layer — the
    // contract that makes G001 impossible among allowed edges
    let layer = |m: &str| {
        LAYERS
            .iter()
            .find(|(n, _)| *n == m)
            .map(|(_, l)| *l)
            .unwrap_or_else(|| panic!("module {m} missing from LAYERS"))
    };
    for (from, deps) in ALLOWED {
        for to in *deps {
            assert!(
                layer(to) < layer(from),
                "ALLOWED edge {from} -> {to} does not descend the layer table"
            );
        }
    }
    // the fingerprint is a pure function of the tables
    assert_eq!(layer_fingerprint().len(), 16);
    assert_eq!(layer_fingerprint(), layer_fingerprint());
}

// ---------------------------------------------------------------------
// Program rules: unused-suppression hygiene (A002)
// ---------------------------------------------------------------------

#[test]
fn unused_allow_is_a002_unless_escaped() {
    let src = "// lint:allow(D001) nothing on the next line actually uses a hash container\n\
               pub fn f() -> f64 { 1.0 }\n";
    let files = tree(&[("rust/src/util/tidy.rs", src)]);

    let report = lint_sources(&files, &LintOptions::default());
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    assert_eq!(report.findings[0].rule, "A002");
    assert!(
        report.findings[0].message.contains("silences nothing"),
        "{}",
        report.findings[0].message
    );

    // --allow-unused: the mid-refactor escape hatch
    let relaxed = lint_sources(&files, &LintOptions { allow_unused: true });
    assert!(relaxed.findings.is_empty(), "{:?}", relaxed.findings);
}

#[test]
fn malformed_allows_stay_a001_not_a002() {
    // unknown rule id + short justification: one A001 each, never A002
    let src = "// lint:allow(Z999) ten chars ok\n\
               // lint:allow(D001) short\n\
               pub fn f() -> f64 { 1.0 }\n";
    let report = lint_sources(
        &tree(&[("rust/src/util/tidy.rs", src)]),
        &LintOptions::default(),
    );
    let rules: Vec<&str> = report.findings.iter().map(|f| f.rule).collect();
    assert_eq!(rules, ["A001", "A001"], "{:?}", report.findings);
}

// ---------------------------------------------------------------------
// Parser round-trip over real sources
// ---------------------------------------------------------------------

#[test]
fn item_spans_partition_real_repo_files() {
    // the parser must account for every token of real code, not just
    // synthetic snippets: spans sorted, non-overlapping, covering
    // [0, token_count) exactly
    let sources: &[(&str, &str)] = &[
        ("rust/src/analysis/graph.rs", include_str!("../src/analysis/graph.rs")),
        ("rust/src/util/codec.rs", include_str!("../src/util/codec.rs")),
        ("rust/src/sim/selector.rs", include_str!("../src/sim/selector.rs")),
        ("rust/src/delay/eval.rs", include_str!("../src/delay/eval.rs")),
    ];
    for (rel, src) in sources {
        let pf = parse_file(rel, src);
        assert!(!pf.items.is_empty(), "{rel}: no items parsed");
        let mut pos = 0usize;
        for item in &pf.items {
            assert_eq!(item.lo, pos, "{rel}: gap/overlap at token {pos}");
            assert!(item.hi > item.lo, "{rel}: empty span");
            pos = item.hi;
        }
        assert_eq!(pos, pf.token_count, "{rel}: trailing tokens unparsed");
        assert!(!pf.fns.is_empty(), "{rel}: no functions found");
        for f in &pf.fns {
            assert!(!f.key.is_empty());
            assert!(f.key.starts_with(&pf.module), "{rel}: key {} module {}", f.key, pf.module);
        }
    }
}

// ---------------------------------------------------------------------
// Repo-wide gate
// ---------------------------------------------------------------------

/// The repo itself must be lint-clean: zero unsuppressed findings
/// (lexical, taint and layering alike), every suppression justified,
/// and the architecture report byte-stable. This is the same gate
/// `sfllm lint` and the CI `lint` job enforce.
#[test]
fn repo_is_lint_clean() {
    // lint:allow(D005) compile-time anchor to locate the repo root from the test binary
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let report = lint_repo(&root, &LintOptions::default()).expect("lint walk succeeds");
    assert!(report.files_scanned > 50, "walk truncated: {} files", report.files_scanned);
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {} ({})", f.file, f.line, f.rule, f.message, f.snippet))
        .collect();
    assert!(report.findings.is_empty(), "lint findings:\n{}", rendered.join("\n"));
    for s in &report.suppressions {
        let ok = s.justification.chars().count() >= 10;
        assert!(ok, "{}:{}: suppression without a justification", s.file, s.line);
    }

    // the layering contract holds on the real tree
    assert_eq!(report.arch.count("G001"), 0);
    assert_eq!(report.arch.count("G002"), 0);
    assert!(report.arch.modules.len() >= 10, "{} modules", report.arch.modules.len());
    assert_eq!(report.arch.fingerprint, layer_fingerprint());

    let json = report.to_json();
    let parsed = sfllm::util::json::Json::parse(&json).expect("report JSON parses");
    let schema = parsed
        .get("schema")
        .and_then(|j| j.as_str())
        .expect("schema field");
    assert_eq!(schema, "sfllm-lint-v2");
    let count = parsed
        .get("finding_count")
        .and_then(|j| j.as_usize())
        .expect("finding_count field");
    assert_eq!(count, 0);
    let fp = parsed
        .get("arch_fingerprint")
        .and_then(|j| j.as_str())
        .expect("arch_fingerprint field");
    assert_eq!(fp, layer_fingerprint());
}

/// ARCH.json and the dot rendering must be byte-stable: two
/// independent walks of the same tree serialize identically (the CI
/// job runs the comparison with `cmp`).
#[test]
fn arch_report_is_byte_stable_across_runs() {
    // lint:allow(D005) compile-time anchor to locate the repo root from the test binary
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let a = lint_repo(&root, &LintOptions::default()).expect("first walk");
    let b = lint_repo(&root, &LintOptions::default()).expect("second walk");
    assert_eq!(a.arch.to_json(), b.arch.to_json());
    assert_eq!(a.arch.to_dot(), b.arch.to_dot());
    assert_eq!(a.to_json(), b.to_json());
    let parsed = sfllm::util::json::Json::parse(&a.arch.to_json()).expect("ARCH.json parses");
    let schema = parsed
        .get("schema")
        .and_then(|j| j.as_str())
        .expect("schema field");
    assert_eq!(schema, "sfllm-arch-v1");
    let g001 = parsed
        .get("g001")
        .and_then(|j| j.as_usize())
        .expect("g001 field");
    let g002 = parsed
        .get("g002")
        .and_then(|j| j.as_usize())
        .expect("g002 field");
    assert_eq!((g001, g002), (0, 0));
}
