//! Self-tests for `sfllm-lint` (PR-7).
//!
//! Two layers:
//!
//! 1. **Fixture corpus** (`tests/lint_fixtures/`): one firing and one
//!    clean fixture per rule ID, embedded with `include_str!` and fed
//!    through [`sfllm::analysis::check_source`] under a synthetic
//!    repo-relative path (hot-path rules get an `rust/src/opt/` path).
//!    A firing fixture must produce findings for exactly its rule; a
//!    clean fixture must produce none.
//! 2. **Repo-wide gate**: the real tree walk must come back with zero
//!    unsuppressed findings — the same invariant the CI `lint` job and
//!    `sfllm lint` enforce.

use sfllm::analysis::{check_source, lint_repo, rule_ids};

/// Synthetic path for rules that apply to all non-test library code.
const SRC_REL: &str = "rust/src/fake/mod.rs";
/// Synthetic path inside the hot scope (`opt/`, `delay/`, `sim/`).
const HOT_REL: &str = "rust/src/opt/fixture.rs";

struct Case {
    rule: &'static str,
    rel: &'static str,
    fire: &'static str,
    clean: &'static str,
    /// Finding count expected from the firing fixture.
    expected: usize,
}

const CASES: &[Case] = &[
    Case {
        rule: "D001",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d001_fire.rs"),
        clean: include_str!("lint_fixtures/d001_clean.rs"),
        expected: 3,
    },
    Case {
        rule: "D002",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d002_fire.rs"),
        clean: include_str!("lint_fixtures/d002_clean.rs"),
        expected: 1,
    },
    Case {
        rule: "D003",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d003_fire.rs"),
        clean: include_str!("lint_fixtures/d003_clean.rs"),
        expected: 2,
    },
    Case {
        rule: "D004",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/d004_fire.rs"),
        clean: include_str!("lint_fixtures/d004_clean.rs"),
        expected: 1,
    },
    Case {
        rule: "N001",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/n001_fire.rs"),
        clean: include_str!("lint_fixtures/n001_clean.rs"),
        expected: 1,
    },
    Case {
        rule: "N002",
        rel: HOT_REL,
        fire: include_str!("lint_fixtures/n002_fire.rs"),
        clean: include_str!("lint_fixtures/n002_clean.rs"),
        expected: 2,
    },
    Case {
        rule: "P001",
        rel: HOT_REL,
        fire: include_str!("lint_fixtures/p001_fire.rs"),
        clean: include_str!("lint_fixtures/p001_clean.rs"),
        expected: 2,
    },
    Case {
        rule: "P002",
        rel: HOT_REL,
        fire: include_str!("lint_fixtures/p002_fire.rs"),
        clean: include_str!("lint_fixtures/p002_clean.rs"),
        expected: 1,
    },
    Case {
        rule: "A001",
        rel: SRC_REL,
        fire: include_str!("lint_fixtures/a001_fire.rs"),
        clean: include_str!("lint_fixtures/a001_clean.rs"),
        expected: 2,
    },
];

#[test]
fn every_rule_has_a_fixture_pair() {
    let covered: Vec<&str> = CASES.iter().map(|c| c.rule).collect();
    for id in rule_ids() {
        let n = covered.iter().filter(|&&r| r == id).count();
        assert_eq!(n, 1, "rule {id} needs exactly one fixture case");
    }
    assert_eq!(covered.len(), rule_ids().len());
}

#[test]
fn firing_fixtures_fire_exactly_their_rule() {
    for c in CASES {
        let (findings, _) = check_source(c.rel, c.fire);
        assert_eq!(findings.len(), c.expected, "{}: got {findings:?}", c.rule);
        for f in &findings {
            assert_eq!(f.rule, c.rule, "{}: stray finding {f:?}", c.rule);
            assert_eq!(f.file, c.rel);
            assert!(f.line > 0);
            assert!(!f.snippet.is_empty());
            assert!(!f.message.is_empty());
        }
    }
}

#[test]
fn clean_fixtures_are_clean() {
    for c in CASES {
        let (findings, _) = check_source(c.rel, c.clean);
        assert!(findings.is_empty(), "{} clean fixture fired: {findings:?}", c.rule);
    }
}

#[test]
fn clean_suppressions_are_marked_used() {
    let a001_clean = include_str!("lint_fixtures/a001_clean.rs");
    let (findings, sups) = check_source(SRC_REL, a001_clean);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(sups.len(), 2);
    for s in &sups {
        assert!(s.used, "suppression at line {} should be used", s.line);
        assert_eq!(s.rules, ["D001"]);
    }
}

#[test]
fn suppression_covers_its_own_line() {
    let src = "use std::collections::HashMap; // lint:allow(D001) membership probe only here\n";
    let (findings, sups) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
    assert_eq!(sups.len(), 1);
    assert!(sups[0].used);
}

#[test]
fn standalone_suppression_covers_the_next_code_line() {
    let src = "// lint:allow(D001) membership probe only here\n\
               use std::collections::HashMap;\n";
    let (findings, sups) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
    assert!(sups[0].used);
}

#[test]
fn suppression_does_not_reach_two_lines_down() {
    let src = "// lint:allow(D001) membership probe only here\n\
               fn pad() {}\n\
               use std::collections::HashMap;\n";
    let (findings, sups) = check_source(SRC_REL, src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "D001");
    assert_eq!(findings[0].line, 3);
    assert!(!sups[0].used, "suppression two lines up must not apply");
}

#[test]
fn empty_rule_list_is_a001() {
    let src = "// lint:allow() forgot to name the rule being suppressed\nfn f() {}\n";
    let (findings, _) = check_source(SRC_REL, src);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert_eq!(findings[0].rule, "A001");
}

#[test]
fn strings_and_comments_never_trigger_rules() {
    let src = "// prose mentioning HashMap and Instant::now is fine\n\
               pub fn banner() -> &'static str {\n\
                   \"HashMap thread_rng Instant::now partial_cmp\"\n\
               }\n";
    let (findings, _) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn partial_cmp_definitions_are_exempt() {
    // Implementing PartialOrd *defines* partial_cmp; only call sites
    // are NaN hazards.
    let src = "struct W(u64);\n\
               impl PartialOrd for W {\n\
                   fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {\n\
                       Some(self.0.cmp(&other.0))\n\
                   }\n\
               }\n";
    let (findings, _) = check_source(HOT_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn cfg_test_blocks_are_exempt_from_lib_rules() {
    let src = "#[cfg(test)]\n\
               mod tests {\n\
                   use std::collections::HashMap;\n\
                   #[test]\n\
                   fn t() {\n\
                       let m: HashMap<u32, u32> = HashMap::new();\n\
                       assert!(m.is_empty());\n\
                   }\n\
               }\n";
    let (findings, _) = check_source(SRC_REL, src);
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn hot_rules_do_not_apply_outside_the_hot_scope() {
    // unwrap/expect and literal indexing are only banned in
    // opt/ / delay/ / sim/; elsewhere they are ordinary Rust.
    let src = "pub fn f(xs: &[f64]) -> f64 {\n    xs.first().copied().unwrap() + xs[0]\n}\n";
    let (findings, _) = check_source("rust/src/util/fake.rs", src);
    assert!(findings.is_empty(), "{findings:?}");
}

/// The repo itself must be lint-clean: zero unsuppressed findings, and
/// every suppression must carry a real justification. This is the same
/// gate `sfllm lint` and the CI `lint` job enforce.
#[test]
fn repo_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate dir has a parent")
        .to_path_buf();
    let report = lint_repo(&root).expect("lint walk succeeds");
    assert!(report.files_scanned > 50, "walk truncated: {} files", report.files_scanned);
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {} ({})", f.file, f.line, f.rule, f.message, f.snippet))
        .collect();
    assert!(report.findings.is_empty(), "lint findings:\n{}", rendered.join("\n"));
    for s in &report.suppressions {
        let ok = s.justification.chars().count() >= 10;
        assert!(ok, "{}:{}: suppression without a justification", s.file, s.line);
    }
    let json = report.to_json();
    let parsed = sfllm::util::json::Json::parse(&json).expect("report JSON parses");
    let schema = parsed
        .get("schema")
        .and_then(|j| j.as_str())
        .expect("schema field");
    assert_eq!(schema, "sfllm-lint-v1");
    let count = parsed
        .get("finding_count")
        .and_then(|j| j.as_usize())
        .expect("finding_count field");
    assert_eq!(count, 0);
}
