// D002 firing fixture: wall-clock reads outside src/bench.rs make
// results depend on the machine, not the seeds.
pub fn stamp() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
