// D003 clean fixture: every draw comes from a seeded counter-based
// stream, a pure function of (seed, round).
use crate::util::rng::Rng;

pub fn jitter(seed: u64, round: u64) -> f64 {
    Rng::new(seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)).f64()
}
