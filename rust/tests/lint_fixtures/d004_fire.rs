// D004 firing fixture: iterator reductions in a module that spawns
// threads are where reduction-order bugs hide.
pub fn parallel_total(xs: &[f64]) -> f64 {
    std::thread::scope(|s| {
        s.spawn(|| ());
    });
    xs.iter().sum()
}
