// P001 firing fixture (hot path): unwrap/expect turn a bad scenario
// into a panic instead of a descriptive error.
pub fn last_entry(xs: &[f64]) -> f64 {
    *xs.last().unwrap()
}

pub fn first_entry(xs: &[f64]) -> f64 {
    *xs.first().expect("non-empty")
}
