// P002 firing fixture (hot path): literal indexing panics on an empty
// slice.
pub fn first_rank(ranks: &[usize]) -> usize {
    ranks[0]
}
