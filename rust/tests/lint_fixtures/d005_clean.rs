// D005 clean fixture: runtime knobs arrive through configuration the
// caller resolved once at the entry point (main.rs is the sanctioned
// environment reader), so library behavior is a function of its
// arguments alone.
pub struct Knobs {
    pub threads: usize,
    pub profile: Option<String>,
}

pub fn threads(knobs: &Knobs) -> usize {
    knobs.threads.max(1)
}

pub fn profile(knobs: &Knobs) -> &str {
    knobs.profile.as_deref().unwrap_or("default")
}
