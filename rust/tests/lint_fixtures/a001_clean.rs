// A001 clean fixture: a justified suppression that actually silences a
// finding.
// lint:allow(D001) membership-only scratch set; iteration order never observed
use std::collections::HashSet;

pub fn distinct(xs: &[u32]) -> usize {
    // lint:allow(D001) membership-only scratch set; iteration order never observed
    let mut seen = HashSet::new();
    let mut n = 0;
    for &x in xs {
        if seen.insert(x) {
            n += 1;
        }
    }
    n
}
