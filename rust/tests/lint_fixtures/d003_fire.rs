// D003 firing fixture: entropy-based RNG cannot reproduce a run.
pub fn jitter() -> f64 {
    let mut rng = rand::thread_rng();
    rng.sample::<f64>()
}

pub fn noise() -> f64 {
    rand::random::<f64>()
}
