// P001 clean fixture (hot path): descriptive anyhow errors instead of
// panics.
use anyhow::{anyhow, Result};

pub fn last_entry(xs: &[f64]) -> Result<f64> {
    xs.last()
        .copied()
        .ok_or_else(|| anyhow!("empty stage-delay vector"))
}
