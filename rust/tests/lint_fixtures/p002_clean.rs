// P002 clean fixture (hot path): Option-returning accessors make the
// empty case explicit.
pub fn first_rank(ranks: &[usize]) -> Option<usize> {
    ranks.first().copied()
}
