// N002 firing fixture (hot path): f64::max silently drops NaN (the
// PR-4 0*inf bug shape), and bare partial_cmp is a partial order.
pub fn stage_bound(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(0.0f64, f64::max)
}

pub fn better(a: f64, b: f64) -> bool {
    a.partial_cmp(&b) == Some(std::cmp::Ordering::Less)
}
