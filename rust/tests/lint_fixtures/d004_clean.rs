// D004 clean fixture: fixed-order indexed accumulation next to the
// thread spawn keeps the reduction order explicit.
pub fn parallel_total(xs: &[f64]) -> f64 {
    std::thread::scope(|s| {
        s.spawn(|| ());
    });
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
    }
    acc
}
