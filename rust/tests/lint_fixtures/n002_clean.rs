// N002 clean fixture (hot path): route straggler maxes through the
// NaN-propagating util::stats helper; order with total_cmp.
use crate::util::stats::stage_max;

pub fn stage_bound(xs: &[f64]) -> f64 {
    stage_max(xs.iter().copied())
}

pub fn better(a: f64, b: f64) -> bool {
    a.total_cmp(&b) == std::cmp::Ordering::Less
}
