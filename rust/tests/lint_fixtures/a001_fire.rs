// A001 firing fixture: suppressions must carry a real justification
// and reference rules that exist.

// lint:allow(D001) short
pub fn noop() {}

// lint:allow(Z999) unknown rule id with an otherwise fine justification
pub fn noop2() {}
