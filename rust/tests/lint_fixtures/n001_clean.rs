// N001 clean fixture: total_cmp is a total order — NaN sorts, never
// panics.
pub fn argmin(xs: &[f64]) -> usize {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    order[0]
}
