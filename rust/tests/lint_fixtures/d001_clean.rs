// D001 clean fixture: BTreeMap iterates in key order; hash containers
// remain fine inside #[cfg(test)] blocks.
use std::collections::BTreeMap;

pub fn tally(xs: &[u32]) -> BTreeMap<u32, u32> {
    let mut m = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    #[test]
    fn hash_containers_are_fine_in_tests() {
        let mut s = HashSet::new();
        s.insert(1);
        assert!(s.contains(&1));
    }
}
