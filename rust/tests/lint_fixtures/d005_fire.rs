// D005 firing fixture: environment reads in library code make a run's
// output depend on ambient shell state instead of the config file and
// CLI flags the provenance record captures.
pub fn threads() -> usize {
    std::env::var("SFLLM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

pub fn build_dir() -> &'static str {
    env!("CARGO_MANIFEST_DIR")
}

pub fn maybe_profile() -> Option<&'static str> {
    option_env!("SFLLM_PROFILE")
}
