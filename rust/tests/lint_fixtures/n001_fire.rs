// N001 firing fixture: partial_cmp().unwrap() panics on the first NaN
// key (the PR-2 percentile bug shape).
pub fn argmin(xs: &[f64]) -> usize {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    order[0]
}
