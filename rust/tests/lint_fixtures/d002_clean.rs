// D002 clean fixture: provenance timestamps are passed in by the
// caller (the bench harness is the only sanctioned wall-clock reader).
pub fn provenance(unix_time: u64) -> String {
    format!("run at {unix_time}")
}
