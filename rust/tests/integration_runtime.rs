//! Integration: AOT artifacts → PJRT runtime → real training steps.
//!
//! Loads the `micro` variant produced by `make artifacts`, runs the
//! three entry points end-to-end and checks learning actually happens
//! through the split — the Rust-side counterpart of the Python
//! split-consistency tests.

use std::path::PathBuf;

use sfllm::model::lora::AdapterSet;
use sfllm::runtime::{Manifest, SflModel, SflRuntime};

/// Every test here needs `make artifacts` (the Python/JAX AOT export)
/// plus a real PJRT backend; the default offline build stubs the `xla`
/// dependency, so these tests are opt-in. Set `SFLLM_RUNTIME_TESTS=1`
/// (with real artifacts + bindings in place) to run them; otherwise
/// they skip deterministically so tier-1 `cargo test` stays green.
macro_rules! require_runtime {
    () => {
        // lint:allow(D005) opt-in gate for hardware-backed tests; absent var means deterministic skip
        if std::env::var("SFLLM_RUNTIME_TESTS").as_deref() != Ok("1") {
            eprintln!(
                "skipping: set SFLLM_RUNTIME_TESTS=1 and run `make artifacts` \
                 with a real PJRT backend (the offline build stubs `xla`)"
            );
            return;
        }
    };
}

fn artifacts() -> PathBuf {
    // lint:allow(D005) compile-time path to the checked-in artifact dir, not a runtime knob
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> SflRuntime {
    let m = Manifest::load(artifacts()).expect("manifest (run `make artifacts` first)");
    SflRuntime::load(&m, "micro_s1_r2").expect("loading micro variant")
}

fn demo_batch(rt: &SflRuntime) -> (Vec<i32>, Vec<f32>) {
    let n = rt.batch() * rt.seq();
    // deterministic pseudo-tokens in-vocab (micro vocab = 64)
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 7 + 3) % 64) as i32).collect();
    let mask = vec![1.0f32; n];
    (tokens, mask)
}

#[test]
fn client_forward_shapes_and_finiteness() {
    require_runtime!();
    let mut rt = runtime();
    let ad = rt.init_client_adapters();
    let (tokens, _) = demo_batch(&rt);
    let s = rt.client_forward(&ad, &tokens).unwrap();
    assert_eq!(s.len(), rt.batch() * rt.seq() * rt.d_model());
    assert!(s.iter().all(|v| v.is_finite()));
    // not all zeros — embeddings flow through
    assert!(s.iter().any(|&v| v.abs() > 1e-6));
}

#[test]
fn initial_loss_is_near_uniform() {
    require_runtime!();
    // with B=0 adapters and random frozen weights, next-token loss ≈ ln(64)
    let mut rt = runtime();
    let ac = rt.init_client_adapters();
    let asrv = rt.init_server_adapters();
    let (tokens, mask) = demo_batch(&rt);
    let loss = rt.eval_loss(&ac, &asrv, &tokens, &mask).unwrap();
    let uniform = (64f32).ln();
    assert!(
        (loss - uniform).abs() < 1.0,
        "initial loss {loss} vs ln(64)={uniform}"
    );
}

#[test]
fn server_step_outputs_are_consistent() {
    require_runtime!();
    let mut rt = runtime();
    let ac = rt.init_client_adapters();
    let asrv = rt.init_server_adapters();
    let (tokens, mask) = demo_batch(&rt);
    let s = rt.client_forward(&ac, &tokens).unwrap();
    let out = rt.server_step(&asrv, &s, &tokens, &mask).unwrap();
    assert!(out.loss.is_finite() && out.loss > 0.0);
    assert_eq!(out.ds.len(), s.len());
    assert_eq!(out.server_grads.tensors.len(), asrv.tensors.len());
    for (g, p) in out.server_grads.tensors.iter().zip(&asrv.tensors) {
        assert_eq!(g.shape, p.shape, "grad shape of {}", p.name);
        assert!(g.data.iter().all(|v| v.is_finite()));
    }
    // some gradient signal must exist
    assert!(out.server_grads.l2_norm() > 0.0);
    assert!(out.ds.iter().any(|&v| v != 0.0));
}

#[test]
fn client_backward_produces_gradients() {
    require_runtime!();
    let mut rt = runtime();
    let ac = rt.init_client_adapters();
    let asrv = rt.init_server_adapters();
    let (tokens, mask) = demo_batch(&rt);
    let s = rt.client_forward(&ac, &tokens).unwrap();
    let out = rt.server_step(&asrv, &s, &tokens, &mask).unwrap();
    let grads = rt.client_backward(&ac, &tokens, &out.ds).unwrap();
    assert_eq!(grads.tensors.len(), ac.tensors.len());
    assert!(grads.l2_norm() > 0.0, "client grads are all zero");
}

#[test]
fn sgd_through_the_split_reduces_loss() {
    require_runtime!();
    let mut rt = runtime();
    let mut ac = rt.init_client_adapters();
    let mut asrv = rt.init_server_adapters();
    let (tokens, mask) = demo_batch(&rt);
    // LoRA starts at B=0, so dA == 0 on step one and learning ramps up
    // slowly under plain SGD — a hot lr on a fixed batch is appropriate.
    let lr = 1.0f32;
    let l0 = rt.eval_loss(&ac, &asrv, &tokens, &mask).unwrap();
    for _ in 0..30 {
        let s = rt.client_forward(&ac, &tokens).unwrap();
        let out = rt.server_step(&asrv, &s, &tokens, &mask).unwrap();
        let gc = rt.client_backward(&ac, &tokens, &out.ds).unwrap();
        ac.sgd_step(&gc, lr).unwrap();
        asrv.sgd_step(&out.server_grads, lr).unwrap();
    }
    let l1 = rt.eval_loss(&ac, &asrv, &tokens, &mask).unwrap();
    assert!(
        l1 < l0 - 0.05,
        "overfitting a fixed batch must reduce loss: {l0} -> {l1}"
    );
}

#[test]
fn deterministic_execution() {
    require_runtime!();
    let mut rt = runtime();
    let ac = rt.init_client_adapters();
    let (tokens, _) = demo_batch(&rt);
    let s1 = rt.client_forward(&ac, &tokens).unwrap();
    let s2 = rt.client_forward(&ac, &tokens).unwrap();
    assert_eq!(s1, s2);
}

#[test]
fn coordinator_trains_through_pjrt() {
    require_runtime!();
    // the full Algorithm-1 loop over the real runtime (tiny scale)
    use sfllm::coordinator::{train, TrainOptions};
    let opts = TrainOptions {
        clients: 2,
        local_steps: 2,
        global_rounds: 2,
        lr_client: 0.05,
        lr_server: 0.05,
        corpus_size: 64,
        val_size: 16,
        eval_batches: 1,
        non_iid: false,
        optimizer: sfllm::coordinator::OptKind::Adam,
        byte_corpus: true, // micro seq=8 cannot fit E2E samples
        save_adapters: None,
        retry_budget: 2,
        retry_backoff_s: 0.05,
        seed: 3,
    };
    let report = train(&opts, || {
        let m = Manifest::load(artifacts())?;
        Ok(Box::new(SflRuntime::load(&m, "micro_s1_r2")?) as Box<dyn SflModel>)
    })
    .unwrap();
    assert_eq!(report.train_loss.len(), 4);
    assert_eq!(report.fed_rounds, 2);
    assert!(report.final_ppl.is_finite());
    assert!(report.train_loss.iter().all(|l| l.is_finite()));
}

#[test]
fn adapter_upload_size_matches_delay_model() {
    require_runtime!();
    // the runtime's actual adapter byte volume must equal what the
    // Section-V delay model charges (Delta Theta_c)
    let rt = runtime();
    let ac = rt.init_client_adapters();
    let cfg = sfllm::model::Gpt2Config::micro();
    let profile = sfllm::model::WorkloadProfile::new(cfg, 8);
    let expect_bits = profile.client_adapter_bits(1, 2);
    assert_eq!(ac.bits(), expect_bits, "wire format vs delay model");
}

#[test]
fn split_invariance_across_real_artifacts() {
    require_runtime!();
    // Same pretrained weights exported at three split points; with B=0
    // LoRA init the composed loss must be identical regardless of where
    // the model is cut — the invariant that lets P3 move the split.
    let m = Manifest::load(artifacts()).unwrap();
    let mut losses = Vec::new();
    for variant in ["tiny_s1_r4", "tiny_s2_r4", "tiny_s3_r4"] {
        let mut rt = SflRuntime::load(&m, variant).unwrap();
        let n = rt.batch() * rt.seq();
        let tokens: Vec<i32> = (0..n).map(|i| ((i * 11 + 5) % 256) as i32).collect();
        let mask = vec![1.0f32; n];
        let ac = rt.init_client_adapters();
        let asrv = rt.init_server_adapters();
        losses.push(rt.eval_loss(&ac, &asrv, &tokens, &mask).unwrap());
    }
    for w in losses.windows(2) {
        assert!(
            (w[0] - w[1]).abs() < 1e-3,
            "split changed the composed loss: {losses:?}"
        );
    }
}

#[test]
fn pretrained_tiny_fits_training_templates_better_than_uniform() {
    require_runtime!();
    // the tiny weights are build-time pre-trained on templates {0,1}
    // of the schema: its loss on E2E-style data must be far below the
    // uniform-distribution bound ln(256), unlike a raw-init model.
    use sfllm::data::{generate_corpus, Batcher};
    use sfllm::util::rng::Rng;
    let m = Manifest::load(artifacts()).unwrap();
    let mut rt = SflRuntime::load(&m, "tiny_s2_r4").unwrap();
    let corpus = generate_corpus(64, &mut Rng::new(1));
    let b = Batcher::new(&corpus, rt.batch(), rt.seq(), Rng::new(2));
    let batch = b.eval_batch(0);
    let ac = rt.init_client_adapters();
    let asrv = rt.init_server_adapters();
    let loss = rt.eval_loss(&ac, &asrv, &batch.tokens, &batch.mask).unwrap();
    assert!(
        loss < 3.0,
        "pretrained model should be well under ln(256)=5.55, got {loss}"
    );
}
