//! Property tests over the experiment API: every registered policy
//! must return a *feasible* allocation on every scenario preset, and
//! `SweepRunner` must be byte-deterministic across thread counts.

use sfllm::delay::{ConvergenceModel, Scenario};
use sfllm::opt::policy::PolicyOutcome;
use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ScenarioBuilder, SweepAxis, SweepRunner, PRESETS};
use sfllm::util::prop::check;

const RANKS: [usize; 3] = [1, 4, 8];

/// C1/C2/C6 via validate, C4/C5 via power_feasible, plus: every client
/// holds at least one subchannel on both links, and 1 <= l_c < L.
fn assert_feasible(scn: &Scenario, out: &PolicyOutcome) -> Result<(), String> {
    out.alloc
        .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
        .map_err(|e| format!("{}: {e}", out.policy))?;
    if !scn.power_feasible(&out.alloc, 1e-6) {
        return Err(format!("{}: power budget C4/C5 violated", out.policy));
    }
    for k in 0..scn.k() {
        if out.alloc.assign_main[k].is_empty() {
            return Err(format!("{}: client {k} starved on main link", out.policy));
        }
        if out.alloc.assign_fed[k].is_empty() {
            return Err(format!("{}: client {k} starved on fed link", out.policy));
        }
    }
    let l = scn.profile.blocks.len();
    if out.alloc.l_c < 1 || out.alloc.l_c >= l {
        return Err(format!(
            "{}: split l_c={} outside [1, {})",
            out.policy, out.alloc.l_c, l
        ));
    }
    if !out.objective.is_finite() || out.objective <= 0.0 {
        return Err(format!("{}: bad objective {}", out.policy, out.objective));
    }
    Ok(())
}

#[test]
fn every_policy_feasible_on_every_preset() {
    let conv = ConvergenceModel::paper_default();
    for preset in PRESETS {
        let scn = ScenarioBuilder::preset(preset).unwrap().build().unwrap();
        let reg = PolicyRegistry::paper_suite(&RANKS, 42, 2);
        for policy in reg.resolve("all").unwrap() {
            let out = policy
                .solve(&scn, &conv)
                .unwrap_or_else(|e| panic!("{preset}/{}: {e:#}", policy.name()));
            assert_feasible(&scn, &out)
                .unwrap_or_else(|e| panic!("preset {preset}: {e}"));
        }
    }
}

#[test]
fn prop_policies_feasible_on_random_seeds() {
    let conv = ConvergenceModel::paper_default();
    check("policy feasibility over seeds", 0x90C1, 8, |rng| {
        let seed = rng.next_u64();
        let scn = ScenarioBuilder::new()
            .seed(seed)
            .clients(2 + rng.below(4))
            .build()
            .map_err(|e| format!("{e:#}"))?;
        let reg = PolicyRegistry::paper_suite(&RANKS, seed, 1);
        for policy in reg.resolve("all").map_err(|e| format!("{e:#}"))? {
            let out = policy
                .solve(&scn, &conv)
                .map_err(|e| format!("{} (scenario seed {seed:#x}): {e:#}", policy.name()))?;
            assert_feasible(&scn, &out)
                .map_err(|e| format!("scenario seed {seed:#x}: {e}"))?;
        }
        Ok(())
    });
}

fn determinism_runner(threads: usize) -> SweepRunner {
    let base = ScenarioBuilder::new().clients(3).tweak(|c| c.train.seq = 256);
    let reg = PolicyRegistry::paper_suite(&RANKS, 7, 2);
    SweepRunner::new(&base)
        .over(SweepAxis::bandwidth_khz(&[250.0, 500.0]))
        .over(SweepAxis::p_max_dbm(&[33.76, 41.76]))
        .policies(reg.resolve("all").unwrap())
        .threads(threads)
}

#[test]
fn sweep_report_identical_at_any_thread_count() {
    let single = determinism_runner(1).run().unwrap().to_csv_string();
    let multi = determinism_runner(4).run().unwrap().to_csv_string();
    assert_eq!(single, multi, "threads must not change the report bytes");
    assert_eq!(single.trim_end().lines().count(), 1 + 4); // header + 2x2 grid
}

#[test]
fn sweep_csv_file_matches_report_and_creates_dirs() {
    let report = determinism_runner(2).run().unwrap();
    let dir = std::env::temp_dir().join("sfllm_sweep_det");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("nested").join("report.csv");
    report.write_csv(path.to_str().unwrap()).unwrap();
    let on_disk = std::fs::read_to_string(&path).unwrap();
    assert_eq!(on_disk, report.to_csv_string());
    let json_path = dir.join("nested2").join("report.json");
    report.write_json(json_path.to_str().unwrap()).unwrap();
    assert!(json_path.exists());
    std::fs::remove_dir_all(&dir).ok();
}
