//! Properties of the cached delay-evaluation engine (`delay::eval`) and
//! the joint P3×P4 scan built on it:
//!
//! * `DelayEvaluator::eval(l, r)` must match `Scenario::total_delay`
//!   **bit-for-bit** on every scenario preset — the cache is a pure
//!   speedup, never a numerical change;
//! * the joint split×rank scan is never worse than the sequential
//!   P3-then-P4 scans it replaced, on every preset;
//! * a handcrafted regression where the sequential scans provably get
//!   stuck at a coordinate-wise optimum the joint scan escapes;
//! * energy properties: `eval_energy` bit-identical to the closed-form
//!   `total_energy` on every preset, `Weighted{lambda: 0}` reproducing
//!   the delay argmin exactly, and a higher ζ never lowering an
//!   energy-optimal objective.

use sfllm::delay::energy::total_energy;
use sfllm::delay::{Allocation, ConvergenceModel, DelayEvaluator, Scenario};
use sfllm::model::{Gpt2Config, WorkloadProfile};
use sfllm::net::topology::ClientSite;
use sfllm::net::{Link, SubchannelSet, Topology};
use sfllm::opt::bcd;
use sfllm::opt::Objective;
use sfllm::opt::{rank, split};
use sfllm::sim::{ScenarioBuilder, PRESETS};

const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

#[test]
fn evaluator_matches_total_delay_bit_for_bit_on_every_preset() {
    let conv = ConvergenceModel::paper_default();
    for preset in PRESETS {
        let scn = ScenarioBuilder::preset(preset).unwrap().build().unwrap();
        let alloc = bcd::initial_alloc(&scn, (scn.profile.blocks.len() / 2).max(1), 4);
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        for l_c in scn.profile.split_candidates() {
            for &r in &RANKS {
                let mut cand = alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                let want = scn.total_delay(&cand, &conv);
                let got = ev.eval(l_c, r);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{preset} (l_c={l_c}, r={r}): cached {got} vs exact {want}"
                );
            }
        }
    }
}

#[test]
fn eval_energy_matches_total_energy_bit_for_bit_on_every_preset() {
    let conv = ConvergenceModel::paper_default();
    for preset in PRESETS {
        let scn = ScenarioBuilder::preset(preset).unwrap().build().unwrap();
        let alloc = bcd::initial_alloc(&scn, (scn.profile.blocks.len() / 2).max(1), 4);
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        for l_c in scn.profile.split_candidates() {
            for &r in &[1usize, 3, 4, 8] {
                // rank 3 exercises the off-table fallback
                let mut cand = alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                let want = total_energy(&scn, &cand, &conv, scn.objective.zeta);
                let got = ev.eval_energy(l_c, r);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{preset} (l_c={l_c}, r={r}): cached {got} vs exact {want}"
                );
                assert!(!got.is_nan(), "{preset}: NaN energy");
            }
        }
    }
}

#[test]
fn weighted_lambda_zero_reproduces_the_delay_argmin_exactly_on_every_preset() {
    let conv = ConvergenceModel::paper_default();
    for preset in PRESETS {
        let scn = ScenarioBuilder::preset(preset).unwrap().build().unwrap();
        let alloc = bcd::initial_alloc(&scn, (scn.profile.blocks.len() / 2).max(1), 4);
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let (l, r, t) = ev.best_split_rank();
        for obj in [Objective::Delay, Objective::Weighted { lambda: 0.0 }] {
            let c = ev.best_split_rank_obj(&obj);
            assert_eq!((c.l_c, c.rank), (l, r), "{preset} {obj:?}");
            assert_eq!(c.score.to_bits(), t.to_bits(), "{preset} {obj:?}");
        }
    }
}

#[test]
fn higher_zeta_never_lowers_an_energy_optimal_objective_on_every_preset() {
    // total energy is monotone non-decreasing in zeta pointwise (the
    // compute term is linear in it, transmit is constant), so the grid
    // minimum under Objective::Energy must be monotone too
    let conv = ConvergenceModel::paper_default();
    for preset in PRESETS {
        let base = ScenarioBuilder::preset(preset).unwrap();
        let mut prev = 0.0f64;
        for (i, zeta) in [5e-29, 1e-28, 4e-28].into_iter().enumerate() {
            let scn = base
                .clone()
                .tweak(|c| c.objective.zeta = zeta)
                .build()
                .unwrap();
            let alloc = bcd::initial_alloc(&scn, (scn.profile.blocks.len() / 2).max(1), 4);
            let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
            let best = ev.best_split_rank_obj(&Objective::Energy);
            assert!(best.score.is_finite() && best.score > 0.0, "{preset}");
            if i > 0 {
                assert!(
                    best.score >= prev,
                    "{preset}: zeta {zeta} lowered the energy optimum \
                     ({} < {prev})",
                    best.score
                );
            }
            prev = best.score;
        }
    }
}

#[test]
fn joint_scan_never_worse_than_sequential_on_every_preset() {
    let conv = ConvergenceModel::paper_default();
    for preset in PRESETS {
        for (init_l, init_r) in [(1usize, 1usize), (6, 4), (11, 8)] {
            let scn = ScenarioBuilder::preset(preset).unwrap().build().unwrap();
            let init_l = init_l.min(scn.profile.blocks.len() - 1).max(1);
            let alloc = bcd::initial_alloc(&scn, init_l, init_r);

            // sequential P3 -> P4, exactly the old Algorithm 3 inner step
            let (l_seq, t_split) = split::best_split(&scn, &alloc, &conv);
            let mut mid = alloc.clone();
            mid.l_c = l_seq;
            let (_, t_rank) = rank::best_rank(&scn, &mid, &conv, &RANKS);
            let t_seq = t_split.min(t_rank);

            // joint grid scan on the cached evaluator
            let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
            let (_, _, t_joint) = ev.best_split_rank();

            assert!(
                t_joint <= t_seq,
                "{preset} init ({init_l}, {init_r}): joint {t_joint} > sequential {t_seq}"
            );
        }
    }
}

/// One client, one subchannel per link, numbers chosen so that split
/// depth and rank genuinely trade off:
///
/// * server compute is 3x the client per block (f_s = f_k/3 at equal
///   kappa), so at rank 1 the delay strictly falls with deeper splits
///   and sequential P3 drives the split to the deepest candidate;
/// * the federated uplink is slow (~1.64 Mbit/s), so the adapter upload
///   costs ~0.06 s per (rank x client-block) — at the deep split,
///   raising the rank to 8 adds far more upload than the halved E(r)
///   saves, and sequential P4 keeps rank 1;
/// * jointly, a shallow split at rank 8 wins: few client blocks keep
///   the upload small while E(r) still halves.
fn trap_scenario() -> Scenario {
    Scenario {
        profile: WorkloadProfile::new(Gpt2Config::gpt2_s(), 128),
        topo: Topology {
            clients: vec![ClientSite {
                d_main_m: 1.0,
                d_fed_m: 1.0,
                f_cycles: 1.0e9,
            }],
        },
        dynamics: sfllm::config::DynamicsConfig::default(),
        objective: sfllm::config::ObjectiveConfig::default(),
        // snr_coeff = gain_product * client_gain / noise_psd, chosen
        // directly: main uplink 1 Gbit/s (SE = log2(1+1) = 1), fed
        // uplink 1e6 * log2(1 + 2.113) ~ 1.64 Mbit/s at PSD 1 W/Hz.
        main_link: Link {
            subch: SubchannelSet::equal_split(1e9, 1),
            gain_product: 1.0,
            noise_psd: 1.0,
            client_gain: vec![1.0],
        },
        fed_link: Link {
            subch: SubchannelSet::equal_split(1e6, 1),
            gain_product: 1.0,
            noise_psd: 1.0,
            client_gain: vec![2.113],
        },
        kappa_client: 1.0 / 1024.0,
        kappa_server: 1.0 / 1024.0,
        f_server: 1.0e9 / 3.0,
        batch: 4,
        local_steps: 3,
        p_max_w: 1e30,
        p_th_main_w: 1e30,
        p_th_fed_w: 1e30,
    }
}

#[test]
fn sequential_scans_get_trapped_where_the_joint_scan_escapes() {
    let scn = trap_scenario();
    // E(1) = 2 * E(8): the rank-8 payoff the sequential order misses
    let conv = ConvergenceModel::table(vec![(1, 48.0), (8, 24.0)]);
    let ranks = [1usize, 8];
    let alloc = Allocation {
        assign_main: vec![vec![0]],
        assign_fed: vec![vec![0]],
        psd_main: vec![1.0],
        psd_fed: vec![1.0],
        l_c: 6,
        rank: 1,
    };

    // sequential P3 -> P4 lands on (deepest split, rank 1) ...
    let (l_seq, t_split) = split::best_split(&scn, &alloc, &conv);
    assert_eq!(l_seq, scn.profile.blocks.len() - 1, "P3 should go deepest at rank 1");
    let mut mid = alloc.clone();
    mid.l_c = l_seq;
    let (r_seq, t_rank) = rank::best_rank(&scn, &mid, &conv, &ranks);
    assert_eq!(r_seq, 1, "P4 should keep rank 1 at the deep split");
    let t_seq = t_split.min(t_rank);

    // ... while the joint scan finds the shallow high-rank optimum
    let ev = DelayEvaluator::build(&scn, &alloc, &conv, &ranks);
    let (l_joint, r_joint, t_joint) = ev.best_split_rank();
    assert_eq!(r_joint, 8, "joint scan should pick the high rank");
    assert!(
        l_joint < l_seq,
        "joint split {l_joint} should be shallower than sequential {l_seq}"
    );
    assert!(
        t_joint < t_seq * 0.95,
        "joint {t_joint} should strictly beat sequential {t_seq}"
    );

    // and the joint result is the true grid argmin
    for l_c in scn.profile.split_candidates() {
        for &r in &ranks {
            let mut cand = alloc.clone();
            cand.l_c = l_c;
            cand.rank = r;
            assert!(scn.total_delay(&cand, &conv) >= t_joint, "({l_c}, {r}) beats the joint scan");
        }
    }
}

// ---------------------------------------------------------------------------
// PR-5: the delta column engine (RateColumns / ColumnCache) behind the
// round-varying simulator's re-opt path.

#[test]
fn column_cache_delta_updates_are_bit_identical_to_cold_computes() {
    use sfllm::delay::{ColumnCache, RateColumns};
    use sfllm::util::rng::Rng;

    let conv = ConvergenceModel::paper_default();
    let mut scn = ScenarioBuilder::preset("mobile_edge")
        .unwrap()
        .tweak(|c| c.train.seq = 128)
        .build()
        .unwrap();
    let l_mid = (scn.profile.blocks.len() / 2).max(1);
    let alloc_a = bcd::initial_alloc(&scn, l_mid, 4);
    // a second, guaranteed-distinct communication block
    let mut alloc_b = alloc_a.clone();
    alloc_b.l_c = 1;
    alloc_b.rank = 1;
    alloc_b.psd_main.iter_mut().for_each(|p| *p *= 0.5);
    let mut cache = ColumnCache::new(4);
    let mut rng = Rng::new(0xC01);

    for round in 0..12 {
        // drift a random subset of gains (none / some / all)
        let kind = round % 3;
        for k in 0..scn.k() {
            if kind == 1 && rng.f64() < 0.5 {
                continue; // partial drift
            }
            if kind > 0 {
                scn.main_link.client_gain[k] *= rng.range(0.8, 1.25);
                scn.fed_link.client_gain[k] *= rng.range(0.8, 1.25);
            }
        }
        for alloc in [&alloc_a, &alloc_b] {
            let cold = RateColumns::compute(&scn, alloc);
            let cached = cache.columns_for(&scn, alloc).clone();
            for (name, a, b) in [
                ("rate_main", &cold.rate_main, &cached.rate_main),
                ("rate_fed", &cold.rate_fed, &cached.rate_fed),
                ("power_main", &cold.power_main, &cached.power_main),
                ("power_fed", &cold.power_fed, &cached.power_fed),
            ] {
                assert_eq!(a.len(), b.len());
                for (k, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "round {round}: {name}[{k}] diverged: {x} vs {y}"
                    );
                }
            }
        }
    }
    assert_eq!(cache.len(), 2, "two communication blocks -> two entries");

    // and an evaluator built over cached columns serves the exact
    // uncached evaluations
    let cols = cache.columns_for(&scn, &alloc_a).clone();
    let table = std::sync::Arc::new(sfllm::model::WorkloadTable::new(&scn.profile, &RANKS));
    let ev_cached = DelayEvaluator::with_columns(&scn, &conv, table.clone(), cols);
    let ev_cold = DelayEvaluator::new(&scn, &alloc_a, &conv, table);
    for l_c in scn.profile.split_candidates() {
        for &r in &RANKS {
            assert_eq!(
                ev_cached.eval(l_c, r).to_bits(),
                ev_cold.eval(l_c, r).to_bits(),
                "delay diverged at ({l_c}, {r})"
            );
            assert_eq!(
                ev_cached.eval_energy(l_c, r).to_bits(),
                ev_cold.eval_energy(l_c, r).to_bits(),
                "energy diverged at ({l_c}, {r})"
            );
        }
    }
}

#[test]
fn column_cache_evicts_least_recently_used_blocks() {
    use sfllm::delay::ColumnCache;

    let scn = ScenarioBuilder::new().build().unwrap();
    let mut cache = ColumnCache::new(2);
    let a = bcd::initial_alloc(&scn, 6, 4);
    let mut b = a.clone();
    b.psd_main.iter_mut().for_each(|p| *p *= 0.5);
    let mut c = a.clone();
    c.psd_main.iter_mut().for_each(|p| *p *= 0.25);
    cache.columns_for(&scn, &a);
    cache.columns_for(&scn, &b);
    assert_eq!(cache.len(), 2);
    cache.columns_for(&scn, &c); // evicts the LRU entry (a)
    assert_eq!(cache.len(), 2);
    cache.columns_for(&scn, &b); // still cached
    assert_eq!(cache.len(), 2);
}
