//! Properties of the fault-injection and graceful-degradation stack
//! (PR-10), the headline invariants of the fault model:
//!
//! 1. **Bit-transparency** — an empty [`FaultPlan`] moves no bits: on
//!    every preset, `run_faulted(empty)` is byte-identical to `run`,
//!    and every per-round fault counter is zero.
//! 2. **Schedule determinism** — identical fault seeds replay
//!    identical fault schedules (the injector is a pure function of
//!    `(plan, round, k)`), across engines, processes, and services.
//! 3. **Crash-resume identity** — checkpointing in the middle of a
//!    *faulted* run and resuming into a fresh process reproduces the
//!    uninterrupted faulted run byte for byte: nothing about the fault
//!    schedule needs serializing.
//! 4. **Graceful degradation** — a total-outage round is shed by the
//!    feasibility-repair chain, not a panic or an abort; malformed
//!    event lines are a counted skip (lenient) or a line-numbered
//!    error (strict), never a crash.

use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::policy::Proposed;
use sfllm::service::{
    parse_events, parse_events_lenient, AllocatorService, Event, RunMode, RunSpec,
};
use sfllm::sim::faults::matrix_levels;
use sfllm::sim::{
    DynamicOutcome, FaultPlan, Population, PopulationSimulator, ReOptStrategy, RoundRecord,
    RoundSimulator, ScenarioBuilder, PRESETS,
};
use sfllm::util::rng::Rng;

const RANKS: [usize; 2] = [1, 4];
const CONV: [f64; 3] = [4.0, 1.0, 0.85];
const TICK_CAP: usize = 512;

/// A fault spec hot enough that a short run is effectively certain to
/// fire several faults, while leaving most clients healthy per round.
const HOT_FAULTS: &str =
    "crash=0.25:2,stall=0.25:0.5:1,outage=0.2:0.001:1,blackout=0.15:0.01:1,seed=77";

fn short_conv() -> ConvergenceModel {
    ConvergenceModel::fitted(CONV[0], CONV[1], CONV[2])
}

/// A preset's spec shrunk to test size (same shrink as `prop_service`).
fn preset_spec(preset: &str, strategy: &str) -> RunSpec {
    let clients = ScenarioBuilder::preset(preset)
        .unwrap()
        .into_config()
        .system
        .clients
        .min(8);
    let mut spec = RunSpec::preset(preset);
    spec.model = Some("tiny".to_string());
    spec.seq = Some(64);
    spec.ranks = Some(RANKS.to_vec());
    spec.clients = Some(clients);
    spec.conv = Some(CONV);
    spec.strategy = strategy.to_string();
    spec
}

/// A sparse population spec on the metro preset, downscaled.
fn metro_spec(strategy: &str) -> RunSpec {
    let mut spec = RunSpec::preset("metro_population");
    spec.mode = RunMode::Population;
    spec.model = Some("tiny".to_string());
    spec.seq = Some(64);
    spec.ranks = Some(RANKS.to_vec());
    spec.population = Some(300);
    spec.cohort = Some(8);
    spec.conv = Some(CONV);
    spec.strategy = strategy.to_string();
    spec
}

/// Run a spec's scenario through [`RoundSimulator::run_faulted`] on a
/// fresh cache.
fn sim_dynamic(spec: &RunSpec, strategy: ReOptStrategy, plan: &FaultPlan) -> DynamicOutcome {
    let conv = short_conv();
    let scn = ScenarioBuilder::from_config(spec.build_config().unwrap())
        .build()
        .unwrap();
    let cache = WorkloadCache::new();
    let policy = Proposed::with_ranks(&RANKS);
    RoundSimulator::new(&scn, &conv, &cache, &RANKS)
        .run_faulted(&policy, strategy, plan)
        .unwrap()
}

/// Same for [`PopulationSimulator::run_faulted`].
fn sim_population(spec: &RunSpec, strategy: ReOptStrategy, plan: &FaultPlan) -> DynamicOutcome {
    let conv = short_conv();
    let cfg = spec.build_config().unwrap();
    let pop = Population::new(&cfg).unwrap();
    let cache = WorkloadCache::new();
    let policy = Proposed::with_ranks(&RANKS);
    PopulationSimulator::new(&pop, &conv, &cache, &RANKS)
        .run_faulted(&policy, strategy, plan)
        .unwrap()
}

fn assert_rounds_eq(a: &[RoundRecord], b: &[RoundRecord], tag: &str) {
    assert_eq!(a.len(), b.len(), "round count on {tag}");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "round index on {tag}");
        assert_eq!(x.weight.to_bits(), y.weight.to_bits(), "weight r{r} on {tag}");
        assert_eq!(x.delay.to_bits(), y.delay.to_bits(), "delay r{r} on {tag}");
        assert_eq!(x.energy.to_bits(), y.energy.to_bits(), "energy r{r} on {tag}");
        assert_eq!(
            (x.l_c, x.rank, x.active, x.resolved, x.cohort, x.dropped),
            (y.l_c, y.rank, y.active, y.resolved, y.cohort, y.dropped),
            "round shape r{r} on {tag}"
        );
        assert_eq!(
            (x.faults, x.repair_tier),
            (y.faults, y.repair_tier),
            "fault columns r{r} on {tag}"
        );
    }
}

fn assert_outcomes_eq(a: &DynamicOutcome, b: &DynamicOutcome, tag: &str) {
    assert_rounds_eq(&a.rounds, &b.rounds, tag);
    assert_eq!(
        a.realized_delay.to_bits(),
        b.realized_delay.to_bits(),
        "realized delay on {tag}"
    );
    assert_eq!(
        a.realized_energy.to_bits(),
        b.realized_energy.to_bits(),
        "realized energy on {tag}"
    );
    assert_eq!(
        a.static_prediction.to_bits(),
        b.static_prediction.to_bits(),
        "static prediction on {tag}"
    );
    assert_eq!(
        (a.resolves, a.fresh_solves, a.unique_participants, a.deadline_drops),
        (b.resolves, b.fresh_solves, b.unique_participants, b.deadline_drops),
        "counters on {tag}"
    );
    assert_eq!(
        (a.faults_injected, a.repair_max),
        (b.faults_injected, b.repair_max),
        "fault totals on {tag}"
    );
    assert_eq!(
        (a.final_alloc.l_c, a.final_alloc.rank),
        (b.final_alloc.l_c, b.final_alloc.rank),
        "final allocation on {tag}"
    );
}

/// Tick a freshly loaded service to convergence; returns the tick count.
fn tick_to_convergence(svc: &mut AllocatorService) -> usize {
    let mut ticks = 0;
    while !svc.is_finished() {
        assert!(ticks < TICK_CAP, "run did not converge within {TICK_CAP} ticks");
        svc.process(&Event::RoundTick).unwrap();
        ticks += 1;
    }
    ticks
}

/// Drive one uninterrupted service over `events`.
fn drive(events: &[Event]) -> (Vec<RoundRecord>, sfllm::service::RunSummary) {
    let mut svc = AllocatorService::new();
    svc.run_events(events).unwrap();
    (svc.rounds().to_vec(), svc.summary().unwrap())
}

/// Drive `events`, but checkpoint after `split` events, restore into a
/// *fresh* service, and replay the rest there — the crash/recover path.
fn drive_with_resume(
    events: &[Event],
    split: usize,
) -> (Vec<RoundRecord>, sfllm::service::RunSummary) {
    let mut a = AllocatorService::new();
    a.run_events(&events[..split]).unwrap();
    let bytes = a.checkpoint_bytes().unwrap();
    let mut rounds = a.rounds().to_vec();
    drop(a);

    let mut b = AllocatorService::new();
    b.restore(&bytes).unwrap();
    b.run_events(&events[split..]).unwrap();
    rounds.extend(b.rounds().iter().cloned());
    (rounds, b.summary().unwrap())
}

#[test]
fn an_empty_plan_is_bit_transparent_on_every_preset() {
    // three spellings of "no faults" — the default plan `run`
    // delegates to, a parsed `none` spec, and the chaos matrix's
    // `none` level — all byte-identical to the plain run
    let parsed = FaultPlan::parse("none").unwrap();
    let (level, matrix_none) = matrix_levels(0xFA17).into_iter().next().unwrap();
    assert_eq!(level, "none");
    for preset in PRESETS {
        let spec = preset_spec(preset, "periodic:2");
        let clean = sim_dynamic(&spec, ReOptStrategy::Periodic(2), &FaultPlan::default());
        for r in &clean.rounds {
            assert_eq!((r.faults, r.repair_tier), (0, 0), "{preset} r{}", r.round);
        }
        assert_eq!((clean.faults_injected, clean.repair_max), (0, 0), "{preset}");
        let a = sim_dynamic(&spec, ReOptStrategy::Periodic(2), &parsed);
        assert_outcomes_eq(&clean, &a, &format!("{preset}/parsed none"));
        let b = sim_dynamic(&spec, ReOptStrategy::Periodic(2), &matrix_none);
        assert_outcomes_eq(&clean, &b, &format!("{preset}/matrix none"));
    }
}

#[test]
fn an_empty_plan_is_bit_transparent_for_population_runs() {
    let spec = metro_spec("periodic:3");
    let clean = sim_population(&spec, ReOptStrategy::Periodic(3), &FaultPlan::default());
    for r in &clean.rounds {
        assert_eq!((r.faults, r.repair_tier), (0, 0), "metro r{}", r.round);
    }
    let again = sim_population(
        &spec,
        ReOptStrategy::Periodic(3),
        &FaultPlan::parse("none").unwrap(),
    );
    assert_outcomes_eq(&clean, &again, "metro_population/parsed none");
}

#[test]
fn identical_seeds_replay_identical_fault_schedules() {
    // fresh simulator + fresh cache on each run: the schedule must come
    // from the plan's seed alone, never from solver or cache state
    let plan = FaultPlan::parse(HOT_FAULTS).unwrap();
    let spec = preset_spec("mobile_edge", "periodic:2");
    let a = sim_dynamic(&spec, ReOptStrategy::Periodic(2), &plan);
    let b = sim_dynamic(&spec, ReOptStrategy::Periodic(2), &plan);
    assert!(a.faults_injected > 0, "hot plan must actually fire");
    assert_outcomes_eq(&a, &b, "mobile_edge/replay");

    let spec = metro_spec("periodic:3");
    let a = sim_population(&spec, ReOptStrategy::Periodic(3), &plan);
    let b = sim_population(&spec, ReOptStrategy::Periodic(3), &plan);
    assert!(a.faults_injected > 0, "hot plan must fire on the population run");
    assert_outcomes_eq(&a, &b, "metro_population/replay");
}

#[test]
fn service_faulted_replay_matches_the_simulator() {
    // the `faults` key on a scenario_loaded spec routes the same plan
    // through the service: one fault model across both surfaces
    let mut spec = preset_spec("mobile_edge", "periodic:2");
    spec.faults = Some(HOT_FAULTS.to_string());
    let out = sim_dynamic(&spec, ReOptStrategy::Periodic(2), &spec.fault_plan().unwrap());

    let mut svc = AllocatorService::new();
    svc.process(&Event::ScenarioLoaded(spec)).unwrap();
    tick_to_convergence(&mut svc);
    let summary = svc.summary().unwrap();
    assert_rounds_eq(svc.rounds(), &out.rounds, "service vs sim");
    assert_eq!(
        summary.realized_delay.to_bits(),
        out.realized_delay.to_bits(),
        "realized delay"
    );
    assert_eq!(summary.faults_injected, out.faults_injected, "fault totals");
    assert_eq!(summary.repair_max, out.repair_max, "repair tier");
    assert!(summary.faults_injected > 0, "the faulted service run must fault");
    assert_eq!(summary.lines_skipped, 0, "strict in-process replay skips nothing");
}

#[test]
fn faulted_resume_is_bit_identical() {
    // headline invariant 3: crash + restore from the checkpoint in the
    // middle of a *faulted* run == the uninterrupted faulted run. The
    // injector being a pure function of (plan, round, k) is exactly
    // what makes this hold with zero schedule state in the checkpoint.
    let mut spec = preset_spec("mobile_edge", "periodic:2");
    spec.faults = Some(HOT_FAULTS.to_string());
    let mut probe = AllocatorService::new();
    probe.process(&Event::ScenarioLoaded(spec.clone())).unwrap();
    let ticks = tick_to_convergence(&mut probe);
    assert!(ticks >= 2, "need a multi-round run to split");
    drop(probe);

    let mut events = vec![Event::ScenarioLoaded(spec)];
    events.extend((0..ticks).map(|_| Event::RoundTick));
    let (rounds, summary) = drive(&events);
    assert!(summary.faults_injected > 0, "the run under test must fault");
    // split right after load, after the first tick, mid-run (either
    // side of typical fault onsets), and after the last tick
    for split in [1, 2, 1 + ticks / 3, 1 + ticks / 2, 1 + (2 * ticks) / 3, ticks] {
        let tag = format!("faulted dynamic/split {split}");
        let (r2, s2) = drive_with_resume(&events, split);
        assert_rounds_eq(&rounds, &r2, &tag);
        assert_eq!(s2.faults_injected, summary.faults_injected, "{tag}");
        assert_eq!(s2.repair_max, summary.repair_max, "{tag}");
        assert_eq!(
            s2.realized_delay.to_bits(),
            summary.realized_delay.to_bits(),
            "{tag}"
        );
    }
}

#[test]
fn faulted_population_resume_is_bit_identical() {
    let mut spec = metro_spec("periodic:3");
    spec.faults = Some(HOT_FAULTS.to_string());
    let mut probe = AllocatorService::new();
    probe.process(&Event::ScenarioLoaded(spec.clone())).unwrap();
    let ticks = tick_to_convergence(&mut probe);
    assert!(ticks >= 2);
    drop(probe);

    let mut events = vec![Event::ScenarioLoaded(spec)];
    events.extend((0..ticks).map(|_| Event::RoundTick));
    let (rounds, summary) = drive(&events);
    assert!(summary.faults_injected > 0);
    for split in [1, 2, 1 + ticks / 2, ticks] {
        let tag = format!("faulted population/split {split}");
        let (r2, s2) = drive_with_resume(&events, split);
        assert_rounds_eq(&rounds, &r2, &tag);
        assert_eq!(
            (s2.faults_injected, s2.repair_max, s2.deadline_drops),
            (summary.faults_injected, summary.repair_max, summary.deadline_drops),
            "{tag}"
        );
        assert_eq!(
            s2.realized_delay.to_bits(),
            summary.realized_delay.to_bits(),
            "{tag}"
        );
    }
}

#[test]
fn total_outage_is_shed_not_fatal() {
    // outage factor 0 zeroes a client's every subchannel gain: any
    // allocation keeping it is infeasible, so the repair chain must
    // walk to tier 3 (shed) — and the run completes with finite totals
    // instead of aborting. every_round keeps the incumbent from being
    // scored against a dead channel on non-resolve rounds.
    let plan = FaultPlan::parse("outage=0.35:0:1,seed=9").unwrap();
    let spec = preset_spec("mobile_edge", "every_round");
    let out = sim_dynamic(&spec, ReOptStrategy::EveryRound, &plan);
    assert!(out.faults_injected > 0, "outages must fire");
    assert_eq!(out.repair_max, 3, "a total outage forces a tier-3 shed");
    assert!(out.realized_delay.is_finite(), "shed runs must stay finite");
    assert!(out.realized_energy.is_finite());
    let k = out.rounds[0].active;
    for r in &out.rounds {
        assert!(r.repair_tier <= 3, "r{}: tier {}", r.round, r.repair_tier);
        if r.repair_tier == 3 {
            assert!(
                r.active < k,
                "r{}: tier 3 must shed someone (active {} of {k})",
                r.round,
                r.active
            );
            assert!(r.delay.is_finite(), "r{}: shed round must be finite", r.round);
        }
    }
}

/// A healthy event stream whose lines the adversarial tests mutate.
fn valid_stream_lines() -> Vec<String> {
    let spec = preset_spec("mobile_edge", "periodic:2");
    let events = vec![
        Event::ScenarioLoaded(spec),
        Event::RoundTick,
        Event::ClientDropped { id: 1 },
        Event::ChannelDrift,
        Event::ReOptRequested,
        Event::RoundTick,
        Event::ClientRejoined { id: 1 },
        Event::CohortSelected { ids: vec![1, 3, 5] },
        Event::CheckpointRequested { path: Some("ck.sfck".to_string()) },
        Event::Shutdown,
    ];
    events.iter().map(|e| e.to_json_line()).collect()
}

/// The reference semantics both parsers must agree with: each
/// non-blank, non-comment line parses alone or is a skip.
fn reference_parse(text: &str) -> (Vec<Event>, Vec<usize>) {
    let mut events = Vec::new();
    let mut skipped = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match Event::from_json_line(line) {
            Ok(e) => events.push(e),
            Err(_) => skipped.push(i + 1),
        }
    }
    (events, skipped)
}

/// One adversarial text: strict and lenient must agree with the
/// line-by-line reference, never panic, and strict errors must carry a
/// line number.
fn check_adversarial(text: &str, tag: &str) {
    let (ref_events, ref_skipped) = reference_parse(text);
    let (events, skipped) = parse_events_lenient(text);
    assert_eq!(events, ref_events, "lenient events on {tag}");
    let lines: Vec<usize> = skipped.iter().map(|s| s.line).collect();
    assert_eq!(lines, ref_skipped, "lenient skip lines on {tag}");
    for s in &skipped {
        assert!(!s.error.is_empty(), "skip diagnostics on {tag}");
    }
    match parse_events(text) {
        Ok(strict) => {
            assert!(skipped.is_empty(), "strict Ok but lenient skipped on {tag}");
            assert_eq!(strict, events, "strict/lenient agreement on {tag}");
        }
        Err(e) => {
            assert!(!skipped.is_empty(), "strict Err but lenient clean on {tag}");
            let msg = format!("{e:#}");
            assert!(
                msg.contains(&format!("events line {}", ref_skipped[0])),
                "strict error must name line {}: {msg} ({tag})",
                ref_skipped[0]
            );
        }
    }
    // determinism: parsing is a pure function of the text
    let (again, skipped_again) = parse_events_lenient(text);
    assert_eq!(events, again, "lenient determinism on {tag}");
    assert_eq!(skipped, skipped_again, "skip determinism on {tag}");
}

#[test]
fn adversarial_event_streams_never_panic() {
    let lines = valid_stream_lines();
    let clean = lines.join("\n");
    check_adversarial(&clean, "clean");
    let (_, skipped) = parse_events_lenient(&clean);
    assert!(skipped.is_empty(), "the healthy stream must parse clean");

    let mut rng = Rng::new(0x5EED);
    // truncations: cut each line at several byte offsets
    for (i, line) in lines.iter().enumerate() {
        for _ in 0..4 {
            let cut = rng.below(line.len().max(1));
            let mut mangled = lines.clone();
            mangled[i] = line[..cut].to_string();
            check_adversarial(&mangled.join("\n"), &format!("truncate line {i} at {cut}"));
        }
    }
    // bit flips: damage one byte of one line (lossy re-decode keeps
    // the corpus valid UTF-8, like a real mangled log read would)
    for (i, line) in lines.iter().enumerate() {
        for _ in 0..4 {
            let mut bytes = line.as_bytes().to_vec();
            let at = rng.below(bytes.len());
            bytes[at] ^= 1 << rng.below(8);
            let mut mangled = lines.clone();
            mangled[i] = String::from_utf8_lossy(&bytes).into_owned();
            check_adversarial(&mangled.join("\n"), &format!("bit flip line {i} byte {at}"));
        }
    }
    // whole-line garbage, duplicated keys, wrong shapes
    for bad in [
        "not json at all",
        "{",
        "{\"event\":",
        "{\"event\":\"quake\"}",
        "{\"event\":\"round_tick\",\"extra\":1}",
        "{\"event\":\"client_dropped\"}",
        "{\"event\":\"client_dropped\",\"id\":-1}",
        "{\"event\":\"cohort_selected\",\"ids\":[3,1]}",
        "[]",
        "42",
        "{\"event\":\"round_tick\",\"event\":\"round_tick\"}",
        "{\"event\":\"round_tick\",\"event\":\"shutdown\"}",
        "{\"event\":\"client_dropped\",\"id\":1,\"id\":2}",
    ] {
        let mut mangled = lines.clone();
        mangled.insert(3, bad.to_string());
        check_adversarial(&mangled.join("\n"), &format!("inserted '{bad}'"));
        // and the bad line alone
        check_adversarial(bad, &format!("alone '{bad}'"));
    }
    // duplicated whole lines are just more events, not an error
    let mut doubled = lines.clone();
    doubled.insert(2, lines[1].clone());
    check_adversarial(&doubled.join("\n"), "duplicated tick");
}

#[test]
fn corrupt_service_checkpoints_fail_descriptively_and_leave_the_service_reusable() {
    // satellite 1 at the byte level: a bit flip anywhere in a service
    // checkpoint is refused with a CRC diagnostic, and the refusing
    // service is still empty — exactly what lets the CLI retry the
    // rotated .prev artifact after a failed primary restore.
    let spec = preset_spec("paper", "periodic:2");
    let mut svc = AllocatorService::new();
    svc.process(&Event::ScenarioLoaded(spec.clone())).unwrap();
    svc.process(&Event::RoundTick).unwrap();
    let good = svc.checkpoint_bytes().unwrap();
    let consumed = svc.events_consumed();
    drop(svc);

    let mut rng = Rng::new(0xC0DE);
    for trial in 0..32 {
        let mut bad = good.clone();
        let at = rng.below(bad.len());
        bad[at] ^= 1 << rng.below(8);
        if bad == good {
            continue;
        }
        let mut fresh = AllocatorService::new();
        let err = match fresh.restore(&bad) {
            Err(e) => format!("{e:#}"),
            // flips inside the magic/version/fingerprint prefix may be
            // caught by those checks instead of the CRC — but a flip
            // can never restore *successfully*
            Ok(()) => panic!("trial {trial}: corrupt checkpoint restored (byte {at})"),
        };
        assert!(!err.is_empty());
        // the failed restore left the service empty: the good bytes
        // still load (the .prev fallback path in the CLI)
        fresh.restore(&good).unwrap();
        assert_eq!(fresh.events_consumed(), consumed, "trial {trial}");
    }
}
