//! Properties of the allocator service (`service::allocator`), the
//! PR-8 contract:
//!
//! 1. **Replay anchor** — a pure `scenario_loaded` + `round_tick`*
//!    stream reproduces [`RoundSimulator`] (dynamic mode) and
//!    [`PopulationSimulator`] (population mode) bit for bit, on every
//!    preset.
//! 2. **Resume invariant** — *checkpoint after event j, restore into a
//!    fresh process, replay the rest* produces the same rounds and the
//!    same summary as the uninterrupted run, bit for bit, for j ∈
//!    {right after load, first tick, mid-run, last tick} — including
//!    streams that carry control events (forced re-opt, cohort
//!    overrides, membership, extra drift).

use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::policy::Proposed;
use sfllm::service::{AllocatorService, Event, RunMode, RunSpec};
use sfllm::sim::{
    DynamicOutcome, Population, PopulationSimulator, ReOptStrategy, RoundRecord, RoundSimulator,
    ScenarioBuilder, PRESETS,
};

const RANKS: [usize; 2] = [1, 4];
const CONV: [f64; 3] = [4.0, 1.0, 0.85];
const TICK_CAP: usize = 512;

fn short_conv() -> ConvergenceModel {
    ConvergenceModel::fitted(CONV[0], CONV[1], CONV[2])
}

/// A preset's spec shrunk to test size (tiny model, two ranks, K ≤ 8)
/// — the same shrink `prop_population` applies to its configs, so the
/// anchored simulators run on literally equal scenarios.
fn preset_spec(preset: &str, strategy: &str) -> RunSpec {
    let clients = ScenarioBuilder::preset(preset)
        .unwrap()
        .into_config()
        .system
        .clients
        .min(8);
    let mut spec = RunSpec::preset(preset);
    spec.model = Some("tiny".to_string());
    spec.seq = Some(64);
    spec.ranks = Some(RANKS.to_vec());
    spec.clients = Some(clients);
    spec.conv = Some(CONV);
    spec.strategy = strategy.to_string();
    spec
}

/// A sparse population spec on the metro preset, downscaled.
fn metro_spec(strategy: &str) -> RunSpec {
    let mut spec = RunSpec::preset("metro_population");
    spec.mode = RunMode::Population;
    spec.model = Some("tiny".to_string());
    spec.seq = Some(64);
    spec.ranks = Some(RANKS.to_vec());
    spec.population = Some(300);
    spec.cohort = Some(8);
    spec.conv = Some(CONV);
    spec.strategy = strategy.to_string();
    spec
}

/// Tick a freshly loaded service to convergence; returns the tick count.
fn tick_to_convergence(svc: &mut AllocatorService) -> usize {
    let mut ticks = 0;
    while !svc.is_finished() {
        assert!(ticks < TICK_CAP, "run did not converge within {TICK_CAP} ticks");
        svc.process(&Event::RoundTick).unwrap();
        ticks += 1;
    }
    ticks
}

/// Drive one uninterrupted service over `events`.
fn drive(events: &[Event]) -> (Vec<RoundRecord>, sfllm::service::RunSummary) {
    let mut svc = AllocatorService::new();
    svc.run_events(events).unwrap();
    (svc.rounds().to_vec(), svc.summary().unwrap())
}

/// Drive `events`, but checkpoint after `split` events, restore into a
/// *fresh* service (cold caches, rebuilt substrate), and replay the
/// rest there. Returns the concatenated rounds + the final summary.
fn drive_with_resume(
    events: &[Event],
    split: usize,
) -> (Vec<RoundRecord>, sfllm::service::RunSummary) {
    let mut a = AllocatorService::new();
    a.run_events(&events[..split]).unwrap();
    let bytes = a.checkpoint_bytes().unwrap();
    // the header carries the spec fingerprint and the stream position
    let header = sfllm::service::peek_header(&bytes).unwrap();
    assert_eq!(header.events_consumed, split as u64);
    if let Event::ScenarioLoaded(spec) = &events[0] {
        assert_eq!(header.fingerprint, spec.fingerprint());
    }
    let mut rounds = a.rounds().to_vec();
    drop(a);

    let mut b = AllocatorService::new();
    b.restore(&bytes).unwrap();
    assert_eq!(b.events_consumed(), split as u64);
    b.run_events(&events[split..]).unwrap();
    rounds.extend(b.rounds().iter().cloned());
    (rounds, b.summary().unwrap())
}

fn assert_rounds_eq(a: &[RoundRecord], b: &[RoundRecord], tag: &str) {
    assert_eq!(a.len(), b.len(), "round count on {tag}");
    for (x, y) in a.iter().zip(b) {
        let r = x.round;
        assert_eq!(x.round, y.round, "round index on {tag}");
        assert_eq!(x.weight.to_bits(), y.weight.to_bits(), "weight r{r} on {tag}");
        assert_eq!(x.delay.to_bits(), y.delay.to_bits(), "delay r{r} on {tag}");
        assert_eq!(x.energy.to_bits(), y.energy.to_bits(), "energy r{r} on {tag}");
        assert_eq!(
            (x.l_c, x.rank, x.active, x.resolved, x.cohort, x.dropped),
            (y.l_c, y.rank, y.active, y.resolved, y.cohort, y.dropped),
            "round shape r{r} on {tag}"
        );
    }
}

fn assert_summary_eq(
    a: &sfllm::service::RunSummary,
    b: &sfllm::service::RunSummary,
    tag: &str,
) {
    assert_eq!(
        a.realized_delay.to_bits(),
        b.realized_delay.to_bits(),
        "realized delay on {tag}"
    );
    assert_eq!(
        a.realized_energy.to_bits(),
        b.realized_energy.to_bits(),
        "realized energy on {tag}"
    );
    assert_eq!(
        a.static_prediction.to_bits(),
        b.static_prediction.to_bits(),
        "static prediction on {tag}"
    );
    assert_eq!(
        (a.rounds, a.resolves, a.fresh_solves, a.deadline_drops),
        (b.rounds, b.resolves, b.fresh_solves, b.deadline_drops),
        "summary counters on {tag}"
    );
    assert_eq!(
        (a.unique_participants, a.final_l_c, a.final_rank, a.converged),
        (b.unique_participants, b.final_l_c, b.final_rank, b.converged),
        "summary identity on {tag}"
    );
}

fn assert_service_matches_outcome(
    rounds: &[RoundRecord],
    summary: &sfllm::service::RunSummary,
    out: &DynamicOutcome,
    tag: &str,
) {
    assert_rounds_eq(rounds, &out.rounds, tag);
    assert_eq!(
        summary.realized_delay.to_bits(),
        out.realized_delay.to_bits(),
        "realized delay on {tag}"
    );
    assert_eq!(
        summary.realized_energy.to_bits(),
        out.realized_energy.to_bits(),
        "realized energy on {tag}"
    );
    assert_eq!(
        summary.static_prediction.to_bits(),
        out.static_prediction.to_bits(),
        "static prediction on {tag}"
    );
    assert_eq!(summary.resolves, out.resolves, "resolves on {tag}");
    assert_eq!(summary.fresh_solves, out.fresh_solves, "fresh solves on {tag}");
    assert_eq!(summary.deadline_drops, out.deadline_drops, "deadline drops on {tag}");
    assert_eq!(
        summary.unique_participants, out.unique_participants,
        "unique participants on {tag}"
    );
    assert_eq!(
        (summary.final_l_c, summary.final_rank),
        (out.final_alloc.l_c, out.final_alloc.rank),
        "final allocation on {tag}"
    );
    assert!(summary.converged, "service run must converge on {tag}");
}

/// Checkpoint split points for a run of `ticks` rounds: right after
/// `scenario_loaded` (round 0 still pending), after the first tick,
/// mid-run, and after the last tick (events are 1 load + `ticks`
/// ticks).
fn splits(ticks: usize) -> Vec<usize> {
    let mut s = vec![1, 2, 1 + ticks / 2, ticks];
    s.dedup();
    s
}

#[test]
fn service_replay_matches_round_simulator_on_every_preset() {
    let conv = short_conv();
    for preset in PRESETS {
        let spec = preset_spec(preset, "periodic:2");
        let scn = ScenarioBuilder::from_config(spec.build_config().unwrap())
            .build()
            .unwrap();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let out = RoundSimulator::new(&scn, &conv, &cache, &RANKS)
            .run(&policy, ReOptStrategy::Periodic(2))
            .unwrap();

        let mut svc = AllocatorService::new();
        svc.process(&Event::ScenarioLoaded(spec)).unwrap();
        tick_to_convergence(&mut svc);
        let summary = svc.summary().unwrap();
        assert_service_matches_outcome(svc.rounds(), &summary, &out, preset);
    }
}

#[test]
fn service_replay_matches_population_simulator() {
    let conv = short_conv();
    // sparse (selection, deadlines, rebasing) and dense (full
    // participation over the evolved environment) population runs
    let mut dense = preset_spec("paper", "periodic:2");
    dense.mode = RunMode::Population;
    dense.population = Some(4);
    dense.cohort = Some(4);
    dense.clients = None; // population mode ignores system.clients
    for (tag, spec, strat) in [
        ("metro_sparse", metro_spec("periodic:3"), ReOptStrategy::Periodic(3)),
        ("paper_dense", dense, ReOptStrategy::Periodic(2)),
    ] {
        let cfg = spec.build_config().unwrap();
        let pop = Population::new(&cfg).unwrap();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let out = PopulationSimulator::new(&pop, &conv, &cache, &RANKS)
            .run(&policy, strat)
            .unwrap();

        let mut svc = AllocatorService::new();
        svc.process(&Event::ScenarioLoaded(spec)).unwrap();
        tick_to_convergence(&mut svc);
        let summary = svc.summary().unwrap();
        assert_service_matches_outcome(svc.rounds(), &summary, &out, tag);
    }
}

#[test]
fn resume_is_bit_identical_on_every_preset() {
    for preset in PRESETS {
        let spec = preset_spec(preset, "periodic:2");
        let mut probe = AllocatorService::new();
        probe.process(&Event::ScenarioLoaded(spec.clone())).unwrap();
        let ticks = tick_to_convergence(&mut probe);
        assert!(ticks >= 2, "{preset}: need a multi-round run to split");
        drop(probe);

        let mut events = vec![Event::ScenarioLoaded(spec)];
        events.extend((0..ticks).map(|_| Event::RoundTick));
        let (rounds, summary) = drive(&events);
        for split in splits(ticks) {
            let tag = format!("{preset}/split {split}");
            let (r2, s2) = drive_with_resume(&events, split);
            assert_rounds_eq(&rounds, &r2, &tag);
            assert_summary_eq(&summary, &s2, &tag);
        }
    }
}

#[test]
fn resume_is_bit_identical_for_population_runs() {
    let spec = metro_spec("periodic:3");
    let mut probe = AllocatorService::new();
    probe.process(&Event::ScenarioLoaded(spec.clone())).unwrap();
    let ticks = tick_to_convergence(&mut probe);
    assert!(ticks >= 2);
    drop(probe);

    let mut events = vec![Event::ScenarioLoaded(spec)];
    events.extend((0..ticks).map(|_| Event::RoundTick));
    let (rounds, summary) = drive(&events);
    for split in splits(ticks) {
        let tag = format!("metro_population/split {split}");
        let (r2, s2) = drive_with_resume(&events, split);
        assert_rounds_eq(&rounds, &r2, &tag);
        assert_summary_eq(&summary, &s2, &tag);
    }
}

#[test]
fn resume_preserves_pending_control_events() {
    // Dynamic mode: membership flips, an extra drift step, and a forced
    // re-opt interleaved with ticks — checkpoints land both *between*
    // control events and *after* a pending force (force_reopt = true is
    // serialized, so the resumed run's next tick still re-solves).
    let spec = preset_spec("mobile_edge", "one_shot");
    let events = vec![
        Event::ScenarioLoaded(spec),
        Event::RoundTick,
        Event::ClientDropped { id: 1 },
        Event::RoundTick,
        Event::ChannelDrift,
        Event::ReOptRequested,
        Event::RoundTick,
        Event::ClientRejoined { id: 1 },
        Event::RoundTick,
        Event::RoundTick,
    ];
    let (rounds, summary) = drive(&events);
    assert!(rounds[2].resolved, "the forced re-opt must have resolved");
    for split in 1..events.len() {
        let tag = format!("controls/split {split}");
        let (r2, s2) = drive_with_resume(&events, split);
        assert_rounds_eq(&rounds, &r2, &tag);
        assert_summary_eq(&summary, &s2, &tag);
    }

    // Population mode: a cohort override pending at checkpoint time
    // must survive the round trip and steer the resumed tick.
    let spec = metro_spec("one_shot");
    let events = vec![
        Event::ScenarioLoaded(spec),
        Event::RoundTick,
        Event::CohortSelected { ids: vec![3, 7, 21, 50, 101, 160, 222, 280] },
        Event::ReOptRequested,
        Event::RoundTick,
        Event::RoundTick,
    ];
    let (rounds, summary) = drive(&events);
    assert_eq!(rounds[1].cohort, 8, "override cohort size");
    for split in 1..events.len() {
        let tag = format!("cohort override/split {split}");
        let (r2, s2) = drive_with_resume(&events, split);
        assert_rounds_eq(&rounds, &r2, &tag);
        assert_summary_eq(&summary, &s2, &tag);
    }
}

#[test]
fn restore_refuses_a_foreign_fingerprint_mode() {
    // A checkpoint is tied to its spec: loading bytes whose mode byte
    // was tampered with is refused (the spec JSON and the mode tag are
    // cross-checked).
    let mut svc = AllocatorService::new();
    svc.process(&Event::ScenarioLoaded(preset_spec("paper", "one_shot")))
        .unwrap();
    svc.process(&Event::RoundTick).unwrap();
    let bytes = svc.checkpoint_bytes().unwrap();
    let header = sfllm::service::peek_header(&bytes).unwrap();
    assert_eq!(header.mode, RunMode::Dynamic);
    assert!(!header.finished);
}
