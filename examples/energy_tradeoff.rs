//! Energy/delay Pareto sweep — the "energy-efficient SflLLM" study the
//! paper names as future work, on the PR-4 objective engine.
//!
//! Sweeps λ of the weighted objective `T + λ·E` from 0 (pure delay)
//! upward, solving the full Algorithm-3 BCD at each point on one shared
//! `WorkloadCache`, and prints the traced Pareto frontier: as λ grows
//! the optimizer gives up delay to buy energy, typically by moving to a
//! shallower split / smaller rank and a leaner power profile. The
//! endpoints are pinned by two extra solves under the pure `delay` and
//! pure `energy` objectives.
//!
//! ```bash
//! cargo run --release --example energy_tradeoff -- \
//!     [--preset battery_edge] [--model tiny] [--lambdas 0,0.01,0.05,0.2,1]
//! ```

use anyhow::{Context, Result};
use sfllm::config::Config;
use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::opt::Objective;
use sfllm::sim::ScenarioBuilder;
use sfllm::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let preset = args.str_or("preset", "battery_edge");
    let lambdas_spec = args.str_or("lambdas", "0,0.005,0.02,0.05,0.2,1");
    let mut cfg = ScenarioBuilder::preset(&preset)?.into_config();
    cfg.apply_file_and_args(&mut args)?;
    args.finish()?;
    let lambdas: Vec<f64> = lambdas_spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<f64>().with_context(|| format!("bad --lambdas entry '{s}'")))
        .collect::<Result<_>>()?;

    let scn = ScenarioBuilder::from_config(cfg.clone()).build()?;
    let conv = ConvergenceModel::paper_default();
    let cache = WorkloadCache::new();
    let solve = |objective: Objective| -> Result<bcd::BcdResult> {
        bcd::optimize_cached(
            &scn,
            &conv,
            &BcdOptions {
                ranks: cfg.train.ranks.clone(),
                objective: Some(objective),
                ..BcdOptions::default()
            },
            &cache,
        )
    };

    println!(
        "energy/delay Pareto sweep on preset '{preset}' \
         (model {}, K={}, zeta={:.1e}):",
        cfg.model, cfg.system.clients, cfg.objective.zeta
    );
    println!(
        "{:>12} {:>6} {:>6} {:>14} {:>14}",
        "objective", "l_c", "rank", "delay (s)", "energy (kJ)"
    );
    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for &lambda in &lambdas {
        let res = solve(Objective::Weighted { lambda })?;
        let label = format!("λ={lambda}");
        println!(
            "{label:>12} {:>6} {:>6} {:>14.1} {:>14.2}",
            res.alloc.l_c,
            res.alloc.rank,
            res.delay,
            res.energy / 1e3
        );
        rows.push((label, res.delay, res.energy));
    }
    for (label, objective) in [("delay", Objective::Delay), ("energy", Objective::Energy)] {
        let res = solve(objective)?;
        println!(
            "{label:>12} {:>6} {:>6} {:>14.1} {:>14.2}",
            res.alloc.l_c,
            res.alloc.rank,
            res.delay,
            res.energy / 1e3
        );
        rows.push((label.to_string(), res.delay, res.energy));
    }

    // frontier sanity: more weight on energy never buys *more* energy
    let first = rows.first().expect("at least one lambda");
    let last = rows[lambdas.len().saturating_sub(1)].clone();
    println!(
        "\nλ={} → λ={}: delay {:+.1}%, energy {:+.1}% — the traced \
         frontier of the delay/energy trade (paper Sec. VIII future work).",
        lambdas.first().copied().unwrap_or(0.0),
        lambdas.last().copied().unwrap_or(0.0),
        100.0 * (last.1 / first.1 - 1.0),
        100.0 * (last.2 / first.2 - 1.0),
    );
    Ok(())
}
