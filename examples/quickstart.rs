//! Quickstart: load an AOT artifact, run one split training step, and
//! one resource-allocation solve — the whole public API in ~60 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::runtime::{Manifest, SflModel, SflRuntime};
use sfllm::sim::ScenarioBuilder;

fn main() -> Result<()> {
    // ---- 1. the compute path: one split LoRA training step ------------
    let manifest = Manifest::load("artifacts")?;
    let mut rt = SflRuntime::load(&manifest, "micro_s1_r2")?;
    println!(
        "loaded micro variant: B={} T={} d={} (split l_c={}, rank={})",
        rt.batch(),
        rt.seq(),
        rt.d_model(),
        rt.variant.l_c,
        rt.variant.rank
    );

    let mut client_adapters = rt.init_client_adapters();
    let mut server_adapters = rt.init_server_adapters();
    let n = rt.batch() * rt.seq();
    let tokens: Vec<i32> = (0..n).map(|i| ((i * 5 + 1) % 64) as i32).collect();
    let mask = vec![1.0f32; n];

    // Algorithm 1, phases a-f, one step:
    let s = rt.client_forward(&client_adapters, &tokens)?; // a: client FP
    let out = rt.server_step(&server_adapters, &s, &tokens, &mask)?; // c-e
    let client_grads = rt.client_backward(&client_adapters, &tokens, &out.ds)?; // f
    client_adapters.sgd_step(&client_grads, 0.5)?; // Eq. 6
    server_adapters.sgd_step(&out.server_grads, 0.5)?; // Eq. 5
    println!("one SFL step done: loss = {:.4}", out.loss);

    // ---- 2. the coordination path: joint resource allocation ----------
    let scn = ScenarioBuilder::preset("paper")?.build()?; // Table II, GPT2-S workload
    let conv = ConvergenceModel::paper_default();
    let res = bcd::optimize(&scn, &conv, &BcdOptions::default())?;
    println!(
        "BCD optimizer: split l_c={}, rank r={}, total training delay {:.1} s \
         ({} iterations)",
        res.alloc.l_c, res.alloc.rank, res.objective, res.iterations
    );
    Ok(())
}
