//! End-to-end driver: full SfLLM fine-tuning of the tiny GPT-2 on the
//! synthetic E2E-style corpus through ALL layers of the stack —
//! L1 Pallas kernels inside L2 AOT artifacts, executed by the L3 Rust
//! coordinator (Algorithm 1: K parallel clients, main server, federated
//! server, FedAvg every I steps) — while the Section-V delay model
//! prices each round on the paper's Table-II wireless scenario.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example e2e_sfl_training -- \
//!     [--rounds 25] [--clients 5] [--variant tiny_s2_r4] [--non-iid]
//! ```
//!
//! Writes `results/e2e_train_loss.csv` + `results/e2e_val_loss.csv` and
//! prints the simulated-network round time for the chosen allocation.
//! EXPERIMENTS.md records a reference run.

use anyhow::Result;
use sfllm::coordinator::{train, OptKind, TrainOptions};
use sfllm::delay::ConvergenceModel;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::runtime::{Manifest, SflModel, SflRuntime};
use sfllm::sim::ScenarioBuilder;
use sfllm::util::cli::Args;
use sfllm::util::csv::CsvWriter;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let variant = args.str_or("variant", "tiny_s2_r4");
    let opts = TrainOptions {
        clients: args.usize_or("clients", 5)?,
        local_steps: args.usize_or("local-steps", 12)?,
        global_rounds: args.usize_or("rounds", 25)?,
        lr_client: args.f64_or("lr", 1e-3)? as f32,
        lr_server: args.f64_or("lr", 1e-3)? as f32,
        corpus_size: args.usize_or("corpus", 2000)?,
        val_size: args.usize_or("val", 200)?,
        eval_batches: args.usize_or("eval-batches", 4)?,
        non_iid: args.flag("non-iid"),
        optimizer: OptKind::Adam,
        byte_corpus: false,
        save_adapters: Some("results/e2e_adapters".into()),
        seed: args.u64_or("seed", 42)?,
    };
    args.finish()?;

    println!("=== SfLLM end-to-end: variant {variant}, K={}, I={}, E={} ===",
        opts.clients, opts.local_steps, opts.global_rounds);

    // ---- real training through the three-layer stack -------------------
    let v2 = variant.clone();
    let report = train(&opts, move || {
        let m = Manifest::load("artifacts")?;
        Ok(Box::new(SflRuntime::load(&m, &v2)?) as Box<dyn SflModel>)
    })?;

    let mut w = CsvWriter::create("results/e2e_train_loss.csv", &["step", "loss"])?;
    for (i, l) in report.train_loss.iter().enumerate() {
        w.row_f64(&[(i + 1) as f64, *l])?;
    }
    w.flush()?;
    let mut w = CsvWriter::create("results/e2e_val_loss.csv", &["step", "val_loss", "ppl"])?;
    for &(s, l) in &report.val_loss {
        w.row_f64(&[s as f64, l, l.exp()])?;
    }
    w.flush()?;

    println!("loss curve (validation, after each aggregation):");
    for &(s, l) in &report.val_loss {
        let bar = "#".repeat(((l - 1.0).max(0.0) * 12.0) as usize);
        println!("  step {s:5}  {l:7.4}  {bar}");
    }
    println!(
        "train loss: {:.4} -> {:.4} | final val ppl {:.4} | fed rounds {}",
        report.train_loss.first().unwrap(),
        report.train_loss.last().unwrap(),
        report.final_ppl,
        report.fed_rounds
    );
    println!(
        "wall: total {:.1}s, server compute {:.1}s, aggregation {:.3}s, eval {:.1}s",
        report.walltime.total,
        report.walltime.server_compute,
        report.walltime.aggregation,
        report.walltime.evaluation
    );

    // ---- price the run on the paper's wireless scenario -----------------
    // (the delay simulator uses the tiny model's own workload profile)
    let scn = ScenarioBuilder::new()
        .model("tiny")
        .clients(opts.clients)
        .tweak(|c| {
            c.train.seq = 64;
            c.train.batch = 8;
        })
        .build()?;
    let conv = ConvergenceModel::table(vec![(4, opts.global_rounds as f64)]);
    let res = bcd::optimize(
        &scn,
        &conv,
        &BcdOptions {
            ranks: vec![4],
            init_rank: 4, // price the run at the trained rank
            ..BcdOptions::default()
        },
    )?;
    let ph = scn.phase_delays(&res.alloc);
    println!("\nsimulated wireless round (Table II channel, tiny workload):");
    println!(
        "  T_local = {:.4}s (client fwd+up {:.4}s | server fwd {:.4}s bwd {:.4}s | client bwd {:.4}s)",
        ph.t_local(),
        ph.client_fwd
            .iter()
            .zip(&ph.act_upload)
            .map(|(a, b)| a + b)
            .fold(0.0f64, f64::max),
        ph.server_fwd,
        ph.server_bwd,
        ph.client_bwd.iter().copied().fold(0.0f64, f64::max),
    );
    println!(
        "  fed upload max {:.4}s | total simulated fine-tuning delay {:.1}s",
        ph.t_fed(),
        res.objective
    );
    println!("results in results/e2e_train_loss.csv, results/e2e_val_loss.csv");
    Ok(())
}
