//! Rank ablation: how the LoRA rank trades per-round cost against
//! convergence speed (the paper's Sec. V discussion and subproblem P4).
//!
//! For each candidate rank, re-optimizes communication (Algorithm 2 +
//! exact P2) with the rank frozen and reports per-round delay, E(r),
//! total delay and total energy — showing why the optimizer's chosen
//! rank wins even when a smaller rank has the cheaper round.
//!
//! All solves share one `WorkloadCache`, and the energy column comes
//! straight off the cached engine (`BcdResult::energy`, produced by
//! `DelayEvaluator::eval_energy` — bit-identical to the closed-form
//! `delay::energy::total_energy`, with zero per-candidate allocation).
//!
//! ```bash
//! cargo run --release --example rank_sweep -- [--model gpt2-s]
//! ```

use anyhow::Result;
use sfllm::config::Config;
use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::opt::Objective;
use sfllm::sim::ScenarioBuilder;
use sfllm::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let cfg = Config::from_args(&mut args)?;
    args.finish()?;
    let scn = ScenarioBuilder::from_config(cfg.clone()).build()?;
    let conv = ConvergenceModel::paper_default();
    let cache = WorkloadCache::new();
    let objective = Objective::from_config(&scn.objective)?;

    println!(
        "rank sweep on {} (K={}, Table II channel):",
        cfg.model, cfg.system.clients
    );
    println!(
        "{:>5} {:>10} {:>12} {:>12} {:>14} {:>14}",
        "rank", "E(r)", "T_local (s)", "T_fed (s)", "total T (s)", "energy (kJ)"
    );
    let mut best = (0usize, f64::INFINITY);
    for &r in &cfg.train.ranks {
        // freeze the rank, optimize everything else; every solve reuses
        // the shared workload cache
        let res = bcd::optimize_cached(
            &scn,
            &conv,
            &BcdOptions {
                ranks: vec![r],
                init_rank: r, // freeze: search set and start are both {r}
                ..BcdOptions::default()
            },
            &cache,
        )?;
        let ph = scn.phase_delays(&res.alloc);
        // the table always reports delay/energy in their own columns;
        // the solve minimizes whatever --objective asked for
        println!(
            "{:>5} {:>10.1} {:>12.4} {:>12.4} {:>14.1} {:>14.2}",
            r,
            conv.rounds(r),
            ph.t_local(),
            ph.t_fed(),
            res.delay,
            res.energy / 1e3,
        );
        if res.objective < best.1 {
            best = (r, res.objective);
        }
    }
    println!(
        "\nbest rank: {} (objective '{}' = {:.1}) — per-round cost rises \
         with rank but E(r) falls; the optimum balances the two (paper \
         Fig. 4-6 narrative).\n\
         The energy column is this repo's future-work extension (paper \
         Sec. VIII): the delay-optimal rank is not automatically the \
         energy-optimal one — run `--objective energy` (or see \
         examples/energy_tradeoff.rs) to optimize that axis instead.",
        best.0,
        objective.label(),
        best.1
    );
    Ok(())
}
