//! Population-engine walk-through on the `metro_population` preset: a
//! fleet of 10^5 modeled clients whose channel/compute state is lazily
//! materialized from per-client seeded streams, with a 64-client cohort
//! re-selected every round and the slowest 10% of each cohort cut by the
//! straggler deadline. The example plays the same seeded fleet out under
//! every cohort-selection policy × re-optimization strategy and compares
//! realized total fine-tuning delay, solver work, and how far into the
//! population each selector reached.
//!
//! Per-round cost is O(cohort), not O(population) — only the selected
//! cohort is ever lowered into a `Scenario` for the incremental solver.
//!
//! ```bash
//! cargo run --release --example population_selection -- \
//!     [--population 100000] [--cohort 64] [--deadline-drop 0.1] \
//!     [--selectors uniform,weighted,staleness:5] \
//!     [--strategies one_shot,periodic:5]
//! ```

use anyhow::Result;
use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::PolicyRegistry;
use sfllm::sim::{Population, PopulationSimulator, ReOptStrategy, ScenarioBuilder};
use sfllm::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let selectors_spec = args.str_or("selectors", "uniform,weighted,staleness:5");
    let strategies_spec = args.str_or("strategies", "one_shot,periodic:5");
    let mut cfg = ScenarioBuilder::preset("metro_population")?.into_config();
    cfg.apply_file_and_args(&mut args)?;
    args.finish()?;

    println!(
        "=== metro_population: {} modeled clients | cohort {} | deadline cuts slowest {:.0}% ===",
        cfg.population.size,
        cfg.population.cohort,
        100.0 * cfg.population.deadline_drop
    );
    let d = &cfg.dynamics;
    println!(
        "    dynamics: rho={} | jitter {} | dropout {}/{}",
        d.rho, d.compute_jitter, d.dropout, d.rejoin
    );

    let conv = ConvergenceModel::paper_default();
    let cache = WorkloadCache::new();
    let reg = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, 3);
    let proposed = reg.get("proposed")?;

    let mut strategies = Vec::new();
    for spec in strategies_spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        strategies.push(ReOptStrategy::parse(spec)?);
    }

    for sel in selectors_spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let mut scfg = cfg.clone();
        scfg.population.selector = sel.to_string();
        let pop = Population::new(&scfg)?;
        let sim = PopulationSimulator::new(&pop, &conv, &cache, &scfg.train.ranks);
        println!("\nselector {}:", pop.selector_label());
        let mut one_shot = None;
        for &strategy in &strategies {
            let out = sim.run(proposed.as_ref(), strategy)?;
            let vs = match one_shot {
                Some(base) if base > 0.0 && strategy != ReOptStrategy::OneShot => {
                    format!(" ({:+.1}% vs one_shot)", 100.0 * (out.realized_delay / base - 1.0))
                }
                _ => String::new(),
            };
            if strategy == ReOptStrategy::OneShot {
                one_shot = Some(out.realized_delay);
            }
            println!(
                "  {:<14} realized {:>9.1} s{vs} | {} rounds | {} fresh solves | \
                 reached {} clients | {} deadline cuts",
                strategy.label(),
                out.realized_delay,
                out.rounds.len(),
                out.fresh_solves,
                out.unique_participants,
                out.deadline_drops
            );
        }
    }

    println!(
        "\nEvery number above touched only O(cohort) state per round; the other \
         ~{} clients were advanced in closed form when (re-)selected.",
        cfg.population.size.saturating_sub(cfg.population.cohort)
    );
    Ok(())
}
