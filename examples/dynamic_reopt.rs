//! Round-varying dynamics walk-through on the `mobile_edge` preset:
//! the shadowing drifts as an AR(1) process, client compute jitters and
//! clients occasionally drop out — so the one-shot allocation the
//! static model would ship goes stale. The example plays the same
//! seeded environment out under every re-optimization strategy and
//! compares the *realized* total fine-tuning delay
//! `Σ_e w_e·(I·T_local(e) + max_k T_k^f(e))` against the static Eq. 17
//! prediction.
//!
//! ```bash
//! cargo run --release --example dynamic_reopt -- \
//!     [--preset mobile_edge] [--clients 12] [--seed 42] \
//!     [--strategies one_shot,every_round,periodic:5,on_degrade:0.25]
//! ```

use anyhow::Result;
use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ReOptStrategy, RoundSimulator, ScenarioBuilder};
use sfllm::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let preset = args.str_or("preset", "mobile_edge");
    let strategies_spec = args.str_or(
        "strategies",
        "one_shot,every_round,periodic:5,on_degrade:0.25",
    );
    let mut cfg = ScenarioBuilder::preset(&preset)?.into_config();
    cfg.apply_file_and_args(&mut args)?;
    args.finish()?;
    let builder = ScenarioBuilder::from_config(cfg);
    let cfg = builder.config();

    let d = &cfg.dynamics;
    println!(
        "=== scenario '{preset}': K={} clients | rho={} | jitter {} | dropout {}/{} ===",
        cfg.system.clients, d.rho, d.compute_jitter, d.dropout, d.rejoin
    );
    let scn = builder.build()?;
    let conv = ConvergenceModel::paper_default();
    let cache = WorkloadCache::new();
    let reg = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, 3);
    let proposed = reg.get("proposed")?;
    let sim = RoundSimulator::new(&scn, &conv, &cache, &cfg.train.ranks);

    let mut results: Vec<(String, f64)> = Vec::new();
    for spec in strategies_spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let strategy = ReOptStrategy::parse(spec)?;
        let out = sim.run(proposed.as_ref(), strategy)?;
        println!(
            "  {:<18} realized {:>10.1} s | static prediction {:>10.1} s | \
             {} rounds | {} re-solves",
            strategy.label(),
            out.realized_delay,
            out.static_prediction,
            out.rounds.len(),
            out.resolves
        );
        results.push((strategy.label(), out.realized_delay));
    }

    if let Some((_, one_shot)) = results.iter().find(|(n, _)| n == "one_shot") {
        let one_shot = *one_shot;
        println!("\nre-optimization gain over one_shot:");
        for (name, realized) in &results {
            if name != "one_shot" && one_shot > 0.0 {
                println!(
                    "  {name:<18} {:+.1}% realized delay",
                    100.0 * (realized / one_shot - 1.0)
                );
            }
        }
    }
    Ok(())
}
