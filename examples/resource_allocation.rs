//! Resource allocation walk-through on the paper's Table-II scenario:
//! builds the scenario with [`ScenarioBuilder`], solves it with the
//! `proposed` policy (Algorithm 3, BCD over P1–P4) from the
//! [`PolicyRegistry`], prints the evolving objective and the final
//! subchannel/power/split/rank choices, then compares every registered
//! policy side by side through a single-point [`SweepRunner`].
//!
//! ```bash
//! cargo run --release --example resource_allocation -- \
//!     [--preset paper] [--clients 5] [--seed 42] [--policies all] [--draws 5]
//! ```

use anyhow::Result;
use sfllm::delay::ConvergenceModel;
use sfllm::net::power::watt_to_dbm;
use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ScenarioBuilder, SweepRunner};
use sfllm::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let preset = args.str_or("preset", "paper");
    let spec = args.str_or("policies", "all");
    let draws = args.usize_or("draws", 5)?;
    let mut cfg = ScenarioBuilder::preset(&preset)?.into_config();
    cfg.apply_file_and_args(&mut args)?;
    args.finish()?;
    let builder = ScenarioBuilder::from_config(cfg);
    let cfg = builder.config();

    println!(
        "=== scenario '{preset}': {} | K={} clients | M={} N={} subchannels | B={} kHz ===",
        cfg.model,
        cfg.system.clients,
        cfg.system.subch_main,
        cfg.system.subch_fed,
        cfg.system.bandwidth_main_hz / 1e3
    );
    let scn = builder.build()?;
    for (k, c) in scn.topo.clients.iter().enumerate() {
        println!(
            "  client {k}: f={:.2} GHz, d_main={:.1} m, d_fed={:.1} m",
            c.f_cycles / 1e9,
            c.d_main_m,
            c.d_fed_m
        );
    }

    let conv = ConvergenceModel::paper_default();
    let registry = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, draws);
    let res = registry.get("proposed")?.solve(&scn, &conv)?;

    println!("\nBCD trajectory (total delay, s):");
    for (i, t) in res.trajectory.iter().flatten().enumerate() {
        println!("  iter {i}: {t:.2}");
    }
    println!(
        "\nchosen allocation: split l_c={} (of {} blocks), rank r={}",
        res.alloc.l_c,
        scn.profile.blocks.len(),
        res.alloc.rank
    );
    for k in 0..scn.k() {
        let pm = scn.power_main(&res.alloc, k);
        let pf = scn.power_fed(&res.alloc, k);
        println!(
            "  client {k}: {} main subch @ {:.1} dBm total, {} fed subch @ {:.1} dBm total, \
             R_main={:.2} Mbit/s R_fed={:.2} Mbit/s",
            res.alloc.assign_main[k].len(),
            watt_to_dbm(pm.max(1e-12)),
            res.alloc.assign_fed[k].len(),
            watt_to_dbm(pf.max(1e-12)),
            scn.rate_main(&res.alloc, k) / 1e6,
            scn.rate_fed(&res.alloc, k) / 1e6,
        );
    }
    let ph = scn.phase_delays(&res.alloc);
    println!(
        "\nper-round: T_local={:.3}s (server fwd {:.3}s bwd {:.3}s), fed upload {:.3}s",
        ph.t_local(),
        ph.server_fwd,
        ph.server_bwd,
        ph.t_fed()
    );
    println!("total fine-tuning delay: {:.1} s", res.objective);

    // every registered policy on the same scenario, via a single-point sweep
    println!("\npolicy comparison ({draws} seeded draws per baseline):");
    let report = SweepRunner::new(&builder)
        .policies(registry.resolve(&spec)?)
        .run()?;
    let Some(point) = report.points.first() else {
        report.print_errors();
        anyhow::bail!("scenario could not be evaluated");
    };
    let objectives = point.objectives();
    let reference = objectives
        .first()
        .copied()
        .filter(|&v| v > 0.0)
        .unwrap_or(1.0);
    let baseline_a = report
        .policy_names
        .iter()
        .position(|n| n == "baseline_a")
        .map(|i| objectives[i]);
    for (name, v) in report.policy_names.iter().zip(&objectives) {
        println!("  {name:16} {v:10.1} s   ({:.1}% of {})", 100.0 * v / reference,
                 report.policy_names[0]);
    }
    if let Some(a) = baseline_a {
        println!(
            "\nlatency reduction vs baseline a: {:.0}% (paper reports up to 60%)",
            100.0 * (1.0 - res.objective / a)
        );
    }
    Ok(())
}
