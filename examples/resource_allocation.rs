//! Resource allocation walk-through on the paper's Table-II scenario:
//! runs Algorithm 3 (BCD over P1–P4) for the GPT2-S workload, prints the
//! evolving objective, the final subchannel/power/split/rank choices,
//! and the comparison against baselines a–d.
//!
//! ```bash
//! cargo run --release --example resource_allocation -- [--clients 5] [--seed 42]
//! ```

use anyhow::Result;
use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::net::power::watt_to_dbm;
use sfllm::opt::baselines;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::sim;
use sfllm::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let draws = args.usize_or("draws", 5)?;
    let cfg = Config::from_args(&mut args)?;
    args.finish()?;

    println!(
        "=== scenario: {} | K={} clients | M={} N={} subchannels | B={} kHz ===",
        cfg.model,
        cfg.system.clients,
        cfg.system.subch_main,
        cfg.system.subch_fed,
        cfg.system.bandwidth_main_hz / 1e3
    );
    let scn = sim::build_scenario(&cfg)?;
    for (k, c) in scn.topo.clients.iter().enumerate() {
        println!(
            "  client {k}: f={:.2} GHz, d_main={:.1} m, d_fed={:.1} m",
            c.f_cycles / 1e9,
            c.d_main_m,
            c.d_fed_m
        );
    }

    let conv = ConvergenceModel::paper_default();
    let opts = BcdOptions {
        ranks: cfg.train.ranks.clone(),
        ..BcdOptions::default()
    };
    let res = bcd::optimize(&scn, &conv, &opts)?;

    println!("\nBCD trajectory (total delay, s):");
    for (i, t) in res.trajectory.iter().enumerate() {
        println!("  iter {i}: {t:.2}");
    }
    println!(
        "\nchosen allocation: split l_c={} (of {} blocks), rank r={}",
        res.alloc.l_c,
        scn.profile.blocks.len(),
        res.alloc.rank
    );
    for k in 0..scn.k() {
        let pm = scn.power_main(&res.alloc, k);
        let pf = scn.power_fed(&res.alloc, k);
        println!(
            "  client {k}: {} main subch @ {:.1} dBm total, {} fed subch @ {:.1} dBm total, \
             R_main={:.2} Mbit/s R_fed={:.2} Mbit/s",
            res.alloc.assign_main[k].len(),
            watt_to_dbm(pm.max(1e-12)),
            res.alloc.assign_fed[k].len(),
            watt_to_dbm(pf.max(1e-12)),
            scn.rate_main(&res.alloc, k) / 1e6,
            scn.rate_fed(&res.alloc, k) / 1e6,
        );
    }
    let ph = scn.phase_delays(&res.alloc);
    println!(
        "\nper-round: T_local={:.3}s (server fwd {:.3}s bwd {:.3}s), fed upload {:.3}s",
        ph.t_local(),
        ph.server_fwd,
        ph.server_bwd,
        ph.t_fed()
    );
    println!("total fine-tuning delay: {:.1} s", res.objective);

    println!("\nbaseline comparison ({draws} seeded draws):");
    let [p, a, b, c, d] =
        baselines::compare_all(&scn, &conv, &cfg.train.ranks, cfg.system.seed, draws)?;
    for (name, v) in [("proposed", p), ("a: all random", a), ("b: random comm", b),
                      ("c: random split", c), ("d: random rank", d)] {
        println!("  {name:16} {v:10.1} s   ({:.1}% of baseline a)", 100.0 * v / a);
    }
    println!(
        "\nlatency reduction vs baseline a: {:.0}% (paper reports up to 60%)",
        100.0 * (1.0 - p / a)
    );
    Ok(())
}
